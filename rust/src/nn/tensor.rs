//! Minimal NCHW f32 tensor, plus [`BatchView`] — the borrowed,
//! batch-slab view the compiled graph executor operates on (arena
//! regions are viewed, never copied into owned tensors, so steady-state
//! execution performs no allocation).

use crate::util::rng::Rng;

/// Borrowed view of a batch slab: `bsz` images of per-image shape
/// `[c, h, w]`, stored contiguously image-major (image `b` occupies
/// `data[b·c·h·w .. (b+1)·c·h·w]`). Flat per-image vectors (e.g. FC
/// outputs) use `h = w = 1`.
///
/// All ops write into caller-provided output slices in the same
/// image-major layout and are element-for-element identical to their
/// per-image [`Tensor`] counterparts — batched execution stays
/// bit-identical to the single-image path.
#[derive(Clone, Copy, Debug)]
pub struct BatchView<'a> {
    /// The underlying slab (`bsz · c · h · w` elements).
    pub data: &'a [f32],
    /// Images in the batch.
    pub bsz: usize,
    /// Per-image channels.
    pub c: usize,
    /// Per-image height.
    pub h: usize,
    /// Per-image width.
    pub w: usize,
}

impl<'a> BatchView<'a> {
    /// View `data` as `bsz` images of shape `[c, h, w]`.
    pub fn new(data: &'a [f32], bsz: usize, c: usize, h: usize, w: usize) -> Self {
        assert_eq!(data.len(), bsz * c * h * w, "slab size mismatch");
        Self { data, bsz, c, h, w }
    }

    /// Elements per image.
    #[inline]
    pub fn numel(&self) -> usize {
        self.c * self.h * self.w
    }

    /// One image's contiguous data.
    #[inline]
    pub fn image(&self, bi: usize) -> &'a [f32] {
        let n = self.numel();
        &self.data[bi * n..(bi + 1) * n]
    }

    #[inline]
    fn at(&self, bi: usize, ci: usize, y: usize, x: usize) -> f32 {
        self.data[((bi * self.c + ci) * self.h + y) * self.w + x]
    }

    /// 2-D max pool over every image; `out` is the `[bsz, c, oh, ow]`
    /// output slab.
    pub fn max_pool_into(&self, k: usize, stride: usize, pad: usize, out: &mut [f32]) {
        let (h, w) = (self.h, self.w);
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        assert_eq!(out.len(), self.bsz * self.c * oh * ow);
        for bi in 0..self.bsz {
            for ci in 0..self.c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut m = f32::NEG_INFINITY;
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                if iy < pad || ix < pad {
                                    continue;
                                }
                                let (iy, ix) = (iy - pad, ix - pad);
                                if iy < h && ix < w {
                                    m = m.max(self.at(bi, ci, iy, ix));
                                }
                            }
                        }
                        out[((bi * self.c + ci) * oh + oy) * ow + ox] = m;
                    }
                }
            }
        }
    }

    /// Global average pool over every image; `out` is the
    /// `[bsz, c, 1, 1]` output slab.
    pub fn global_avg_pool_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.bsz * self.c);
        let hw = self.h * self.w;
        let denom = hw as f32;
        for bi in 0..self.bsz {
            for ci in 0..self.c {
                let start = (bi * self.c + ci) * hw;
                let s: f32 = self.data[start..start + hw].iter().sum();
                out[bi * self.c + ci] = s / denom;
            }
        }
    }

    /// Elementwise ReLU into `out` (same slab layout).
    pub fn relu_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len());
        for (o, &v) in out.iter_mut().zip(self.data.iter()) {
            *o = v.max(0.0);
        }
    }

    /// Elementwise add (+ optional fused ReLU) into `out` — residual
    /// connections. Shapes must match.
    pub fn add_into(&self, other: &BatchView<'_>, relu: bool, out: &mut [f32]) {
        assert_eq!(self.data.len(), other.data.len(), "add shape mismatch");
        assert_eq!(out.len(), self.data.len());
        for ((o, &a), &b) in out.iter_mut().zip(self.data.iter()).zip(other.data.iter()) {
            let v = a + b;
            *o = if relu { v.max(0.0) } else { v };
        }
    }

    /// Copy this view's channels into channel offset `c_off` of a
    /// `c_total`-channel output slab with the same batch/spatial dims —
    /// the per-input step of a channel concat (inception blocks).
    pub fn copy_into_channels(&self, c_total: usize, c_off: usize, out: &mut [f32]) {
        assert!(c_off + self.c <= c_total);
        assert_eq!(out.len(), self.bsz * c_total * self.h * self.w);
        let hw = self.h * self.w;
        for bi in 0..self.bsz {
            let src = self.image(bi);
            let dst = (bi * c_total + c_off) * hw;
            out[dst..dst + self.c * hw].copy_from_slice(src);
        }
    }
}

/// A dense f32 tensor with explicit shape (row-major / C order).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn random(shape: &[usize], seed: u64, lo: f32, hi: f32) -> Self {
        let mut t = Self::zeros(shape);
        let mut rng = Rng::new(seed);
        rng.fill_f32(&mut t.data, lo, hi);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// NCHW accessors (shape must be 4-D).
    pub fn nchw(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "expected NCHW, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        let (_, cc, hh, ww) = self.nchw();
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise add (shapes must match) — residual connections.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Concatenate along channels (dim 1, NCHW) — inception blocks.
    pub fn concat_channels(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let (n, _, h, w) = parts[0].nchw();
        let c_total: usize = parts.iter().map(|p| p.nchw().1).sum();
        let mut out = Tensor::zeros(&[n, c_total, h, w]);
        let hw = h * w;
        for ni in 0..n {
            let mut c_off = 0usize;
            for p in parts {
                let (_, pc, ph, pw) = p.nchw();
                assert_eq!((ph, pw), (h, w), "spatial mismatch in concat");
                let src = &p.data[ni * pc * hw..(ni + 1) * pc * hw];
                let dst_start = (ni * c_total + c_off) * hw;
                out.data[dst_start..dst_start + pc * hw].copy_from_slice(src);
                c_off += pc;
            }
        }
        out
    }

    /// 2-D max pool (NCHW).
    pub fn max_pool(&self, k: usize, stride: usize, pad: usize) -> Tensor {
        let (n, c, h, w) = self.nchw();
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        let mut out = Tensor::zeros(&[n, c, oh, ow]);
        for ni in 0..n {
            for ci in 0..c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut m = f32::NEG_INFINITY;
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                if iy < pad || ix < pad {
                                    continue;
                                }
                                let (iy, ix) = (iy - pad, ix - pad);
                                if iy < h && ix < w {
                                    m = m.max(self.at4(ni, ci, iy, ix));
                                }
                            }
                        }
                        out.data[((ni * c + ci) * oh + oy) * ow + ox] = m;
                    }
                }
            }
        }
        out
    }

    /// Global average pool → [N, C, 1, 1].
    pub fn global_avg_pool(&self) -> Tensor {
        let (n, c, h, w) = self.nchw();
        let mut out = Tensor::zeros(&[n, c, 1, 1]);
        let hw = (h * w) as f32;
        for ni in 0..n {
            for ci in 0..c {
                let start = (ni * c + ci) * h * w;
                let s: f32 = self.data[start..start + h * w].iter().sum();
                out.data[ni * c + ci] = s / hw;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_channels_layout() {
        let a = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|x| x as f32).collect());
        let c = Tensor::concat_channels(&[&a, &b]);
        assert_eq!(c.shape, vec![1, 3, 2, 2]);
        assert_eq!(&c.data[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&c.data[4..], &(0..8).map(|x| x as f32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn max_pool_2x2() {
        let t = Tensor::from_vec(
            &[1, 1, 4, 4],
            (0..16).map(|x| x as f32).collect(),
        );
        let p = t.max_pool(2, 2, 0);
        assert_eq!(p.shape, vec![1, 1, 2, 2]);
        assert_eq!(p.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn global_avg_pool_values() {
        let t = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 10., 10., 10., 10.]);
        let p = t.global_avg_pool();
        assert_eq!(p.shape, vec![1, 2, 1, 1]);
        assert_eq!(p.data, vec![2.5, 10.0]);
    }

    #[test]
    fn residual_add() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[1, 1, 1, 2], vec![3.0, 4.0]);
        assert_eq!(a.add(&b).data, vec![4.0, 6.0]);
    }

    /// Every batch op must be bit-identical to the per-image Tensor op.
    #[test]
    fn batch_view_ops_match_per_image_tensors() {
        let (bsz, c, h, w) = (3usize, 4usize, 5usize, 6usize);
        let imgs: Vec<Tensor> =
            (0..bsz).map(|i| Tensor::random(&[1, c, h, w], 90 + i as u64, -2.0, 2.0)).collect();
        let other: Vec<Tensor> =
            (0..bsz).map(|i| Tensor::random(&[1, c, h, w], 70 + i as u64, -2.0, 2.0)).collect();
        let mut slab = Vec::new();
        let mut oslab = Vec::new();
        for (a, b) in imgs.iter().zip(other.iter()) {
            slab.extend_from_slice(&a.data);
            oslab.extend_from_slice(&b.data);
        }
        let v = BatchView::new(&slab, bsz, c, h, w);
        let ov = BatchView::new(&oslab, bsz, c, h, w);

        // max pool (with padding → exercises the skip branches);
        // oh = (5+2-3)/2+1 = 3, ow = (6+2-3)/2+1 = 3.
        let mut got = vec![0f32; bsz * c * 3 * 3];
        v.max_pool_into(3, 2, 1, &mut got);
        for (bi, img) in imgs.iter().enumerate() {
            let want = img.max_pool(3, 2, 1);
            assert_eq!(&got[bi * want.len()..(bi + 1) * want.len()], &want.data[..]);
        }
        // gap
        let mut got = vec![0f32; bsz * c];
        v.global_avg_pool_into(&mut got);
        for (bi, img) in imgs.iter().enumerate() {
            assert_eq!(&got[bi * c..(bi + 1) * c], &img.global_avg_pool().data[..]);
        }
        // relu
        let mut got = vec![0f32; slab.len()];
        v.relu_into(&mut got);
        for (bi, img) in imgs.iter().enumerate() {
            let want = img.map(|x| x.max(0.0));
            assert_eq!(&got[bi * want.len()..(bi + 1) * want.len()], &want.data[..]);
        }
        // add (+relu)
        let mut got = vec![0f32; slab.len()];
        v.add_into(&ov, true, &mut got);
        for (bi, (a, b)) in imgs.iter().zip(other.iter()).enumerate() {
            let want = a.add(b).map(|x| x.max(0.0));
            assert_eq!(&got[bi * want.len()..(bi + 1) * want.len()], &want.data[..]);
        }
        // concat via copy_into_channels
        let c_total = 2 * c;
        let mut got = vec![0f32; bsz * c_total * h * w];
        v.copy_into_channels(c_total, 0, &mut got);
        ov.copy_into_channels(c_total, c, &mut got);
        for (bi, (a, b)) in imgs.iter().zip(other.iter()).enumerate() {
            let want = Tensor::concat_channels(&[a, b]);
            assert_eq!(&got[bi * want.len()..(bi + 1) * want.len()], &want.data[..]);
        }
    }
}
