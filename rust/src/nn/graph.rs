//! Layer graph: a small DAG IR with enough ops to express the paper's
//! CNNs (sequential stacks, residual adds, inception concats). Execution
//! lives in [`crate::engine`]; this module owns structure and weights.

use super::{ConvSpec, Tensor};
use crate::util::rng::Rng;

/// Graph operation.
#[derive(Clone, Debug)]
pub enum Op {
    /// Convolution (+ folded bias, optional fused ReLU — batch norm is
    /// assumed folded into weights/bias as all deployment runtimes do).
    Conv {
        spec: ConvSpec,
        weights: Vec<f32>,
        bias: Vec<f32>,
        relu: bool,
    },
    MaxPool {
        k: usize,
        stride: usize,
        pad: usize,
    },
    GlobalAvgPool,
    /// Fully connected [out_f × in_f] (+ bias). `quant: true` routes the
    /// layer through the quantized pack→LUT pipeline as a 1×1-conv GEMM
    /// (per-image M = 1 — the autoregressive-decode shape the GEMV row
    /// path serves); `false` keeps the batched fp32 GEMM.
    Fc {
        in_f: usize,
        out_f: usize,
        weights: Vec<f32>,
        bias: Vec<f32>,
        quant: bool,
    },
    /// Elementwise add of two inputs (+ optional fused ReLU).
    Add {
        relu: bool,
    },
    Relu,
    /// Channel concat of ≥2 inputs.
    Concat,
    /// Layer normalization over the flattened per-image vector:
    /// `(x - mean) / sqrt(var + eps) * gamma + beta`, with `gamma`/
    /// `beta` of length `dim`.
    LayerNorm {
        dim: usize,
        gamma: Vec<f32>,
        beta: Vec<f32>,
        eps: f32,
    },
    /// Numerically-stable softmax over the flattened per-image vector.
    Softmax,
    /// Single-token multi-head self-attention against a persistent
    /// KV cache. Inputs are `[q, k, v]`, each a flat
    /// `heads * head_dim` vector for the *current* decode position; the
    /// executor appends k/v to the node's KV-cache arena slot (sized
    /// `max_seq × heads × head_dim` at compile time, one slot pair per
    /// attention node), computes `softmax(q·Kᵀ/√head_dim)·V` over
    /// positions `0..=pos`, and advances `pos` once per
    /// `forward_batch` call. The stateless fp32 reference treats every
    /// call as position 0 (softmax over one score is 1, so the output
    /// equals `v`) — enough for calibration; decode semantics are
    /// covered by the engine's differential tests.
    Attention {
        heads: usize,
        head_dim: usize,
        max_seq: usize,
    },
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Conv { .. } => "conv",
            Op::MaxPool { .. } => "maxpool",
            Op::GlobalAvgPool => "gap",
            Op::Fc { .. } => "fc",
            Op::Add { .. } => "add",
            Op::Relu => "relu",
            Op::Concat => "concat",
            Op::LayerNorm { .. } => "layernorm",
            Op::Softmax => "softmax",
            Op::Attention { .. } => "attention",
        }
    }
}

/// A node: op + indices of producer nodes (or the graph input).
#[derive(Clone, Debug)]
pub struct Node {
    pub name: String,
    pub op: Op,
    /// Input node ids; [`Graph::INPUT`] denotes the graph input tensor.
    pub inputs: Vec<usize>,
}

/// A model graph.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    /// (C, H, W) of the expected single-image input.
    pub input_chw: (usize, usize, usize),
    pub nodes: Vec<Node>,
    /// Node id producing the output.
    pub output: usize,
}

impl Graph {
    pub const INPUT: usize = usize::MAX;

    pub fn new(name: impl Into<String>, input_chw: (usize, usize, usize)) -> Self {
        Self { name: name.into(), input_chw, nodes: Vec::new(), output: 0 }
    }

    /// Append a node; returns its id.
    pub fn push(&mut self, name: impl Into<String>, op: Op, inputs: Vec<usize>) -> usize {
        self.nodes.push(Node { name: name.into(), op, inputs });
        let id = self.nodes.len() - 1;
        self.output = id;
        id
    }

    /// Add a conv (+ReLU) with He-initialised random weights.
    pub fn conv(
        &mut self,
        name: impl Into<String>,
        spec: ConvSpec,
        relu: bool,
        input: usize,
        rng: &mut Rng,
    ) -> usize {
        let wlen = spec.weight_len();
        let fan_in = (spec.in_ch / spec.groups * spec.kh * spec.kw) as f32;
        let std = (2.0 / fan_in).sqrt();
        let mut weights = vec![0f32; wlen];
        rng.fill_normal(&mut weights, std);
        let mut bias = vec![0f32; spec.out_ch];
        rng.fill_f32(&mut bias, -0.05, 0.05);
        self.push(name, Op::Conv { spec, weights, bias, relu }, vec![input])
    }

    /// Number of conv nodes.
    pub fn conv_count(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n.op, Op::Conv { .. })).count()
    }

    /// Total conv weight parameters.
    pub fn conv_params(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                Op::Conv { weights, .. } => weights.len(),
                Op::Fc { weights, .. } => weights.len(),
                _ => 0,
            })
            .sum()
    }

    /// Validate topology: inputs reference earlier nodes only.
    pub fn validate(&self) -> crate::Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            for &inp in &n.inputs {
                if inp != Self::INPUT && inp >= i {
                    return Err(crate::Error::Config(format!(
                        "node {i} ({}) references non-causal input {inp}",
                        n.name
                    )));
                }
            }
            let arity_ok = match n.op {
                Op::Add { .. } => n.inputs.len() == 2,
                Op::Concat => n.inputs.len() >= 2,
                Op::Attention { .. } => n.inputs.len() == 3,
                _ => n.inputs.len() == 1,
            };
            if !arity_ok {
                return Err(crate::Error::Config(format!(
                    "node {i} ({}) has wrong arity {}",
                    n.name,
                    n.inputs.len()
                )));
            }
        }
        if self.output >= self.nodes.len() {
            return Err(crate::Error::Config("output id out of range".into()));
        }
        Ok(())
    }

    /// Infer the output shape of every node for a single-image input.
    pub fn infer_shapes(&self) -> crate::Result<Vec<Vec<usize>>> {
        let (c, h, w) = self.input_chw;
        let input_shape = vec![1, c, h, w];
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(self.nodes.len());
        for (i, n) in self.nodes.iter().enumerate() {
            let get = |id: usize| -> crate::Result<&Vec<usize>> {
                if id == Self::INPUT {
                    Ok(&input_shape)
                } else {
                    shapes.get(id).ok_or_else(|| {
                        crate::Error::Config(format!("node {i}: bad input {id}"))
                    })
                }
            };
            let shape = match &n.op {
                Op::Conv { spec, .. } => {
                    let s = get(n.inputs[0])?;
                    if s[1] != spec.in_ch {
                        return Err(crate::Error::Shape(format!(
                            "node {} ({}): in_ch {} != tensor C {}",
                            i, n.name, spec.in_ch, s[1]
                        )));
                    }
                    let (oh, ow) = spec.out_hw(s[2], s[3]);
                    vec![1, spec.out_ch, oh, ow]
                }
                Op::MaxPool { k, stride, pad } => {
                    let s = get(n.inputs[0])?;
                    let oh = (s[2] + 2 * pad - k) / stride + 1;
                    let ow = (s[3] + 2 * pad - k) / stride + 1;
                    vec![1, s[1], oh, ow]
                }
                Op::GlobalAvgPool => {
                    let s = get(n.inputs[0])?;
                    vec![1, s[1], 1, 1]
                }
                Op::Fc { in_f, out_f, .. } => {
                    let s = get(n.inputs[0])?;
                    let flat: usize = s.iter().product();
                    if flat != *in_f {
                        return Err(crate::Error::Shape(format!(
                            "node {} ({}): fc expects {in_f}, got {flat}",
                            i, n.name
                        )));
                    }
                    vec![1, *out_f]
                }
                Op::Add { .. } => {
                    let a = get(n.inputs[0])?.clone();
                    let b = get(n.inputs[1])?;
                    if &a != b {
                        return Err(crate::Error::Shape(format!(
                            "node {} ({}): add shape mismatch {a:?} vs {b:?}",
                            i, n.name
                        )));
                    }
                    a
                }
                Op::Relu => get(n.inputs[0])?.clone(),
                Op::LayerNorm { dim, gamma, beta, .. } => {
                    let s = get(n.inputs[0])?.clone();
                    let flat: usize = s.iter().product();
                    if flat != *dim || gamma.len() != *dim || beta.len() != *dim {
                        return Err(crate::Error::Shape(format!(
                            "node {} ({}): layernorm dim {dim} vs tensor {flat} \
                             (gamma {}, beta {})",
                            i,
                            n.name,
                            gamma.len(),
                            beta.len()
                        )));
                    }
                    s
                }
                Op::Softmax => get(n.inputs[0])?.clone(),
                Op::Attention { heads, head_dim, max_seq } => {
                    let d = heads * head_dim;
                    if *max_seq == 0 || d == 0 {
                        return Err(crate::Error::Shape(format!(
                            "node {} ({}): attention needs heads·head_dim > 0 and max_seq > 0",
                            i, n.name
                        )));
                    }
                    for &inp in &n.inputs {
                        let flat: usize = get(inp)?.iter().product();
                        if flat != d {
                            return Err(crate::Error::Shape(format!(
                                "node {} ({}): attention expects q/k/v of {d} elems, got {flat}",
                                i, n.name
                            )));
                        }
                    }
                    vec![1, d]
                }
                Op::Concat => {
                    let first = get(n.inputs[0])?.clone();
                    let mut c_total = 0usize;
                    for &inp in &n.inputs {
                        let s = get(inp)?;
                        if s[2] != first[2] || s[3] != first[3] {
                            return Err(crate::Error::Shape(format!(
                                "node {} ({}): concat spatial mismatch",
                                i, n.name
                            )));
                        }
                        c_total += s[1];
                    }
                    vec![1, c_total, first[2], first[3]]
                }
            };
            shapes.push(shape);
        }
        Ok(shapes)
    }

    /// Conv layer inventory with resolved input sizes — feeds the
    /// per-layer benches.
    pub fn conv_inventory(&self) -> crate::Result<Vec<(String, ConvSpec, usize, usize)>> {
        let shapes = self.infer_shapes()?;
        let (c, h, w) = self.input_chw;
        let input_shape = vec![1, c, h, w];
        let mut out = Vec::new();
        for n in &self.nodes {
            if let Op::Conv { spec, .. } = &n.op {
                let s = if n.inputs[0] == Self::INPUT {
                    &input_shape
                } else {
                    &shapes[n.inputs[0]]
                };
                out.push((n.name.clone(), *spec, s[2], s[3]));
            }
        }
        Ok(out)
    }
}

/// Numerically-stable in-place softmax over one row (max-subtract →
/// exp → normalize). Shared by the fp32 reference and the compiled
/// executor so both paths are bit-identical, and unit-tested against an
/// f64 naive reference (all-equal logits, large-negative rows,
/// single-element rows).
pub fn softmax_row(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let mut max = f32::MIN;
    for &v in row.iter() {
        max = max.max(v);
    }
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Layer normalization of one row into `out`:
/// `(x - mean) / sqrt(var + eps) * gamma + beta` with population
/// variance. Shared by the fp32 reference and the compiled executor
/// (bit-identical paths); unit-tested against an f64 naive reference.
pub fn layer_norm_row(x: &[f32], gamma: &[f32], beta: &[f32], eps: f32, out: &mut [f32]) {
    let n = x.len();
    debug_assert!(n > 0 && gamma.len() == n && beta.len() == n && out.len() == n);
    let inv_n = 1.0 / n as f32;
    let mut mean = 0f32;
    for &v in x {
        mean += v;
    }
    mean *= inv_n;
    let mut var = 0f32;
    for &v in x {
        let d = v - mean;
        var += d * d;
    }
    var *= inv_n;
    let inv_std = 1.0 / (var + eps).sqrt();
    for i in 0..n {
        out[i] = (x[i] - mean) * inv_std * gamma[i] + beta[i];
    }
}

/// Reference FP32 forward pass (single image) — the semantic oracle that
/// the quantized engines are compared against in integration tests.
pub fn forward_fp32(g: &Graph, x: &Tensor) -> crate::Result<Tensor> {
    let mut outs = forward_fp32_all(g, x)?;
    Ok(outs.swap_remove(g.output))
}

/// [`forward_fp32`] capturing *every* node's output (index = node id) —
/// the one reference evaluator: engine calibration reads per-node
/// intermediates from it, tests read just the graph output via
/// [`forward_fp32`].
pub fn forward_fp32_all(g: &Graph, x: &Tensor) -> crate::Result<Vec<Tensor>> {
    g.validate()?;
    let mut outs: Vec<Tensor> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let get = |id: usize| -> &Tensor {
            if id == Graph::INPUT {
                x
            } else {
                &outs[id]
            }
        };
        let y = match &n.op {
            Op::Conv { spec, weights, bias, relu } => {
                let y = super::im2col::conv2d_direct(get(n.inputs[0]), weights, bias, spec);
                if *relu {
                    y.map(|v| v.max(0.0))
                } else {
                    y
                }
            }
            Op::MaxPool { k, stride, pad } => get(n.inputs[0]).max_pool(*k, *stride, *pad),
            Op::GlobalAvgPool => get(n.inputs[0]).global_avg_pool(),
            Op::Fc { in_f, out_f, weights, bias, .. } => {
                let xin = get(n.inputs[0]);
                let mut y = Tensor::zeros(&[1, *out_f]);
                for o in 0..*out_f {
                    let mut acc = bias[o];
                    for i in 0..*in_f {
                        acc += weights[o * in_f + i] * xin.data[i];
                    }
                    y.data[o] = acc;
                }
                y
            }
            Op::Add { relu } => {
                let y = get(n.inputs[0]).add(get(n.inputs[1]));
                if *relu {
                    y.map(|v| v.max(0.0))
                } else {
                    y
                }
            }
            Op::Relu => get(n.inputs[0]).map(|v| v.max(0.0)),
            Op::LayerNorm { gamma, beta, eps, .. } => {
                let xin = get(n.inputs[0]);
                let mut y = Tensor::zeros(&xin.shape);
                layer_norm_row(&xin.data, gamma, beta, *eps, &mut y.data);
                y
            }
            Op::Softmax => {
                let mut y = get(n.inputs[0]).clone();
                softmax_row(&mut y.data);
                y
            }
            Op::Attention { heads, head_dim, .. } => {
                // Stateless position-0 reference: a one-position KV
                // cache makes the softmax weight exactly 1, so the
                // attention output equals v. Calibration only needs
                // value ranges; decode semantics live in the engine.
                let v = get(n.inputs[2]);
                Tensor::from_vec(&[1, heads * head_dim], v.data.clone())
            }
            Op::Concat => {
                let parts: Vec<&Tensor> = n.inputs.iter().map(|&i| get(i)).collect();
                Tensor::concat_channels(&parts)
            }
        };
        outs.push(y);
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("tiny", (3, 8, 8));
        let mut rng = Rng::new(1);
        let c1 = g.conv("c1", ConvSpec::new(3, 4, 3, 1, 1), true, Graph::INPUT, &mut rng);
        let c2 = g.conv("c2", ConvSpec::new(4, 4, 3, 1, 1), false, c1, &mut rng);
        let add = g.push("res", Op::Add { relu: true }, vec![c1, c2]);
        let gap = g.push("gap", Op::GlobalAvgPool, vec![add]);
        let mut wfc = vec![0f32; 4 * 2];
        rng.fill_normal(&mut wfc, 0.5);
        g.push(
            "fc",
            Op::Fc { in_f: 4, out_f: 2, weights: wfc, bias: vec![0.0; 2], quant: false },
            vec![gap],
        );
        g
    }

    #[test]
    fn validates_and_infers() {
        let g = tiny_graph();
        g.validate().unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[0], vec![1, 4, 8, 8]);
        assert_eq!(shapes[2], vec![1, 4, 8, 8]);
        assert_eq!(shapes[4], vec![1, 2]);
    }

    #[test]
    fn forward_runs_and_relu_applies() {
        let g = tiny_graph();
        let x = Tensor::random(&[1, 3, 8, 8], 5, -1.0, 1.0);
        let y = forward_fp32(&g, &x).unwrap();
        assert_eq!(y.shape, vec![1, 2]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn non_causal_graph_rejected() {
        let mut g = Graph::new("bad", (1, 4, 4));
        g.push("x", Op::Relu, vec![3]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn conv_inventory_resolves_input_sizes() {
        let g = tiny_graph();
        let inv = g.conv_inventory().unwrap();
        assert_eq!(inv.len(), 2);
        assert_eq!(inv[0].2, 8);
        assert_eq!(inv[1].3, 8);
    }

    /// f64 reference softmax (stable form — the mathematically exact
    /// result up to f64 rounding).
    fn softmax_f64(xs: &[f32]) -> Vec<f64> {
        let max = xs.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v as f64));
        let exps: Vec<f64> = xs.iter().map(|&v| (v as f64 - max).exp()).collect();
        let sum: f64 = exps.iter().sum();
        exps.iter().map(|e| e / sum).collect()
    }

    /// f64 reference layer norm (population variance).
    fn layer_norm_f64(xs: &[f32], gamma: &[f32], beta: &[f32], eps: f32) -> Vec<f64> {
        let n = xs.len() as f64;
        let mean: f64 = xs.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 =
            xs.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>() / n;
        let inv_std = 1.0 / (var + eps as f64).sqrt();
        xs.iter()
            .enumerate()
            .map(|(i, &v)| (v as f64 - mean) * inv_std * gamma[i] as f64 + beta[i] as f64)
            .collect()
    }

    fn assert_close_f64(got: &[f32], want: &[f64], tol: f64, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length mismatch");
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g as f64 - w).abs() <= tol * (1.0 + w.abs()),
                "{what}: element {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn softmax_matches_f64_reference() {
        let cases: Vec<Vec<f32>> = vec![
            vec![0.5],                              // single element → exactly 1
            vec![3.0, 3.0, 3.0, 3.0],               // all-equal → uniform
            vec![-1.0e4, -1.0e4 + 1.0, -1.0e4 - 2.0], // large-negative row
            vec![1.0, -2.5, 0.25, 7.5, -0.125],
            vec![88.0, 87.0, -90.0],                // near f32 exp overflow pre-shift
        ];
        for xs in &cases {
            let mut got = xs.clone();
            softmax_row(&mut got);
            let want = softmax_f64(xs);
            assert_close_f64(&got, &want, 1e-5, &format!("softmax {xs:?}"));
            let sum: f32 = got.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax rows must sum to 1, got {sum}");
        }
        let mut one = vec![-123.0f32];
        softmax_row(&mut one);
        assert_eq!(one, vec![1.0], "single-element softmax is exactly one");
        let mut empty: Vec<f32> = vec![];
        softmax_row(&mut empty); // must not panic
    }

    #[test]
    fn layer_norm_matches_f64_reference() {
        let eps = 1e-5f32;
        let cases: Vec<Vec<f32>> = vec![
            vec![4.25],                      // single element → beta exactly
            vec![2.0, 2.0, 2.0],             // all-equal → zero-centred, var 0
            vec![-1.0e4, -1.0e4 + 3.0, -1.0e4 - 3.0], // large-negative row
            vec![0.1, -0.7, 1.3, 2.9, -3.3, 0.0],
        ];
        for xs in &cases {
            let n = xs.len();
            let gamma: Vec<f32> = (0..n).map(|i| 0.5 + 0.25 * i as f32).collect();
            let beta: Vec<f32> = (0..n).map(|i| -0.25 + 0.125 * i as f32).collect();
            let mut got = vec![0f32; n];
            layer_norm_row(xs, &gamma, &beta, eps, &mut got);
            let want = layer_norm_f64(xs, &gamma, &beta, eps);
            assert_close_f64(&got, &want, 1e-4, &format!("layernorm {xs:?}"));
        }
        // Single element: x - mean = 0, so the output is exactly beta.
        let mut got = vec![0f32];
        layer_norm_row(&[7.5], &[2.0], &[0.625], eps, &mut got);
        assert_eq!(got, vec![0.625]);
    }

    #[test]
    fn transformer_ops_validate_and_infer() {
        let mut g = Graph::new("attn", (8, 1, 1));
        let mut rng = Rng::new(2);
        let mut w = vec![0f32; 8 * 8];
        rng.fill_normal(&mut w, 0.3);
        let q = g.push(
            "q",
            Op::Fc { in_f: 8, out_f: 8, weights: w.clone(), bias: vec![0.0; 8], quant: true },
            vec![Graph::INPUT],
        );
        let k = g.push(
            "k",
            Op::Fc { in_f: 8, out_f: 8, weights: w.clone(), bias: vec![0.0; 8], quant: true },
            vec![Graph::INPUT],
        );
        let v = g.push(
            "v",
            Op::Fc { in_f: 8, out_f: 8, weights: w, bias: vec![0.0; 8], quant: true },
            vec![Graph::INPUT],
        );
        let a = g.push(
            "attn",
            Op::Attention { heads: 2, head_dim: 4, max_seq: 16 },
            vec![q, k, v],
        );
        let ln = g.push(
            "ln",
            Op::LayerNorm { dim: 8, gamma: vec![1.0; 8], beta: vec![0.0; 8], eps: 1e-5 },
            vec![a],
        );
        g.push("sm", Op::Softmax, vec![ln]);
        g.validate().unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[a], vec![1, 8]);
        assert_eq!(shapes[ln], vec![1, 8]);
        let x = Tensor::random(&[1, 8, 1, 1], 3, -1.0, 1.0);
        let y = forward_fp32(&g, &x).unwrap();
        assert_eq!(y.shape, vec![1, 8]);
        let sum: f32 = y.data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "softmax output must normalize");
        // Wrong-arity attention is rejected.
        let mut bad = Graph::new("bad", (8, 1, 1));
        bad.push("a", Op::Attention { heads: 2, head_dim: 4, max_seq: 4 }, vec![Graph::INPUT]);
        assert!(bad.validate().is_err());
    }
}
