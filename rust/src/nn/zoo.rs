//! Model zoo — graph builders for every network in the paper's evaluation
//! (Fig. 5/6, Tab. 4/5): MobileNetV1, ResNet18/34/50, ResNeXt101,
//! GoogleNet, InceptionV3, VGG16, plus a small CNN used by tests and the
//! serving demos. Weights are He-initialised from a seed (pretrained
//! checkpoints are not reproducible offline; latency is weight-agnostic).

use super::graph::{Graph, Op};
use super::{ConvSpec, LayerShape};
use crate::util::rng::Rng;

/// All model names available from [`build`] / [`layer_inventory`].
pub const MODELS: [&str; 9] = [
    "small_cnn",
    "mobilenet_v1",
    "resnet18",
    "resnet34",
    "resnet50",
    "resnext101",
    "googlenet",
    "inception_v3",
    "vgg16",
];

/// Build a model graph by name.
pub fn build(name: &str, num_classes: usize, seed: u64) -> crate::Result<Graph> {
    let mut rng = Rng::new(seed);
    let g = match name {
        "small_cnn" => small_cnn(num_classes, &mut rng),
        "mobilenet_v1" => mobilenet_v1(num_classes, &mut rng),
        "resnet18" => resnet(18, num_classes, &mut rng),
        "resnet34" => resnet(34, num_classes, &mut rng),
        "resnet50" => resnet(50, num_classes, &mut rng),
        "resnext101" => resnext101(num_classes, &mut rng),
        "googlenet" => googlenet(num_classes, &mut rng),
        "inception_v3" => inception_v3(num_classes, &mut rng),
        "vgg16" => vgg16(num_classes, &mut rng),
        // Decode workload (not in MODELS — it has no conv inventory):
        // num_classes doubles as the vocab size.
        "tiny_transformer" => tiny_transformer(num_classes, &mut rng),
        other => return Err(crate::Error::Config(format!("unknown model '{other}'"))),
    };
    g.validate()?;
    Ok(g)
}

/// Conv layer inventory (name, spec, input H, input W) for a model —
/// the per-layer (M, N, K) shapes of the paper's Fig. 5.
pub fn layer_inventory(name: &str) -> crate::Result<Vec<LayerShape>> {
    let g = build(name, 1000, 0)?;
    let inv = g.conv_inventory()?;
    // Leak the names: LayerShape carries &'static str for bench labels.
    Ok(inv
        .into_iter()
        .map(|(n, spec, h, w)| LayerShape {
            name: Box::leak(n.into_boxed_str()),
            spec,
            h,
            w,
        })
        .collect())
}

/// Small CNN (CIFAR-scale) for tests, the quickstart and the server demo.
pub fn small_cnn(num_classes: usize, rng: &mut Rng) -> Graph {
    let mut g = Graph::new("small_cnn", (3, 32, 32));
    let c1 = g.conv("conv1", ConvSpec::new(3, 16, 3, 1, 1), true, Graph::INPUT, rng);
    let p1 = g.push("pool1", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![c1]);
    let c2 = g.conv("conv2", ConvSpec::new(16, 32, 3, 1, 1), true, p1, rng);
    let p2 = g.push("pool2", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![c2]);
    let c3 = g.conv("conv3", ConvSpec::new(32, 64, 3, 1, 1), true, p2, rng);
    let gap = g.push("gap", Op::GlobalAvgPool, vec![c3]);
    fc(&mut g, "fc", 64, num_classes, gap, rng);
    g
}

/// Tiny residual + concat graph (CIFAR-scale): two branches concat into
/// a channel-doubled trunk which is then residually added. Exercises the
/// arena planner's multi-consumer liveness (the concat output feeds both
/// the trunk conv *and* the residual add) in tests — not part of the
/// paper's model zoo.
pub fn tiny_mixed(num_classes: usize, rng: &mut Rng) -> Graph {
    let mut g = Graph::new("tiny_mixed", (3, 16, 16));
    let c1 = g.conv("c1", ConvSpec::new(3, 8, 3, 1, 1), true, Graph::INPUT, rng);
    let br_a = g.conv("br_a", ConvSpec::new(8, 8, 3, 1, 1), true, c1, rng);
    let br_b = g.conv("br_b", ConvSpec::new(8, 8, 1, 1, 0), true, c1, rng);
    let cat = g.push("cat", Op::Concat, vec![br_a, br_b]);
    let c2 = g.conv("c2", ConvSpec::new(16, 16, 3, 1, 1), false, cat, rng);
    let res = g.push("res", Op::Add { relu: true }, vec![cat, c2]);
    let pool = g.push("pool", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![res]);
    let gap = g.push("gap", Op::GlobalAvgPool, vec![pool]);
    fc(&mut g, "fc", 16, num_classes, gap, rng);
    g
}

fn fc(g: &mut Graph, name: &str, in_f: usize, out_f: usize, input: usize, rng: &mut Rng) -> usize {
    let mut w = vec![0f32; in_f * out_f];
    rng.fill_normal(&mut w, (1.0 / in_f as f32).sqrt());
    let bias = vec![0f32; out_f];
    g.push(name, Op::Fc { in_f, out_f, weights: w, bias, quant: false }, vec![input])
}

/// A quantized FC: routed through the backend's pack→LUT pipeline as a
/// 1×1-conv GEMM (per-image M = 1 — the GEMV decode shape).
fn qfc(g: &mut Graph, name: &str, in_f: usize, out_f: usize, input: usize, rng: &mut Rng) -> usize {
    let mut w = vec![0f32; in_f * out_f];
    rng.fill_normal(&mut w, (1.0 / in_f as f32).sqrt());
    let mut bias = vec![0f32; out_f];
    rng.fill_f32(&mut bias, -0.02, 0.02);
    g.push(name, Op::Fc { in_f, out_f, weights: w, bias, quant: true }, vec![input])
}

fn layer_norm(g: &mut Graph, name: &str, dim: usize, input: usize, rng: &mut Rng) -> usize {
    let mut gamma = vec![0f32; dim];
    rng.fill_f32(&mut gamma, 0.8, 1.2);
    let mut beta = vec![0f32; dim];
    rng.fill_f32(&mut beta, -0.05, 0.05);
    g.push(name, Op::LayerNorm { dim, gamma, beta, eps: 1e-5 }, vec![input])
}

/// `tiny_transformer` geometry: (d_model, heads, head_dim, ffn width,
/// layers, max decode positions). d_model = heads · head_dim.
pub const TINY_TRANSFORMER_DIMS: (usize, usize, usize, usize, usize, usize) =
    (32, 4, 8, 64, 2, 64);

/// Tiny 2-layer pre-norm decoder-only transformer for the
/// autoregressive-decode workload: per step the graph input is one
/// token's `d_model` embedding, every projection (q/k/v/out and the
/// FFN) is a *quantized* FC running the pack→LUT pipeline at per-image
/// M = 1 (the GEMV row path), attention keeps a per-node KV cache in
/// the arena (capacity `max_seq` positions), and a final fp32 FC
/// produces `vocab` logits. See `docs/TRANSFORMER.md`.
pub fn tiny_transformer(vocab: usize, rng: &mut Rng) -> Graph {
    let (d, heads, head_dim, ffn, layers, max_seq) = TINY_TRANSFORMER_DIMS;
    let mut g = Graph::new("tiny_transformer", (d, 1, 1));
    // Input projection: maps the [1, d, 1, 1] input into a flat [1, d]
    // node so residual adds compare identical shapes downstream.
    let mut cur = qfc(&mut g, "embed", d, d, Graph::INPUT, rng);
    for l in 0..layers {
        let ln1 = layer_norm(&mut g, &format!("l{l}.ln1"), d, cur, rng);
        let q = qfc(&mut g, &format!("l{l}.q"), d, d, ln1, rng);
        let k = qfc(&mut g, &format!("l{l}.k"), d, d, ln1, rng);
        let v = qfc(&mut g, &format!("l{l}.v"), d, d, ln1, rng);
        let attn = g.push(
            format!("l{l}.attn"),
            Op::Attention { heads, head_dim, max_seq },
            vec![q, k, v],
        );
        let proj = qfc(&mut g, &format!("l{l}.proj"), d, d, attn, rng);
        let res1 = g.push(format!("l{l}.add1"), Op::Add { relu: false }, vec![cur, proj]);
        let ln2 = layer_norm(&mut g, &format!("l{l}.ln2"), d, res1, rng);
        let ff1 = qfc(&mut g, &format!("l{l}.ff1"), d, ffn, ln2, rng);
        let act = g.push(format!("l{l}.act"), Op::Relu, vec![ff1]);
        let ff2 = qfc(&mut g, &format!("l{l}.ff2"), ffn, d, act, rng);
        cur = g.push(format!("l{l}.add2"), Op::Add { relu: false }, vec![res1, ff2]);
    }
    let lnf = layer_norm(&mut g, "ln_f", d, cur, rng);
    fc(&mut g, "logits", d, vocab, lnf, rng);
    g
}

/// MobileNetV1 (1.0×, 224) — depthwise-separable stacks.
pub fn mobilenet_v1(num_classes: usize, rng: &mut Rng) -> Graph {
    let mut g = Graph::new("mobilenet_v1", (3, 224, 224));
    let mut cur = g.conv("conv1", ConvSpec::new(3, 32, 3, 2, 1), true, Graph::INPUT, rng);
    // (in, out, stride of the depthwise)
    let cfg: [(usize, usize, usize); 13] = [
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ];
    for (i, &(cin, cout, s)) in cfg.iter().enumerate() {
        let dw = ConvSpec::new(cin, cin, 3, s, 1).grouped(cin);
        cur = g.conv(format!("dw{}", i + 1), dw, true, cur, rng);
        let pw = ConvSpec::new(cin, cout, 1, 1, 0);
        cur = g.conv(format!("pw{}", i + 1), pw, true, cur, rng);
    }
    let gap = g.push("gap", Op::GlobalAvgPool, vec![cur]);
    fc(&mut g, "fc", 1024, num_classes, gap, rng);
    g
}

/// ResNet-18/34 (basic blocks) and ResNet-50 (bottlenecks).
pub fn resnet(depth: usize, num_classes: usize, rng: &mut Rng) -> Graph {
    let (blocks, bottleneck): ([usize; 4], bool) = match depth {
        18 => ([2, 2, 2, 2], false),
        34 => ([3, 4, 6, 3], false),
        50 => ([3, 4, 6, 3], true),
        _ => panic!("unsupported resnet depth {depth}"),
    };
    let mut g = Graph::new(format!("resnet{depth}"), (3, 224, 224));
    let c1 = g.conv("conv1", ConvSpec::new(3, 64, 7, 2, 3), true, Graph::INPUT, rng);
    let mut cur = g.push("pool1", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![c1]);
    let widths = [64usize, 128, 256, 512];
    let expansion = if bottleneck { 4 } else { 1 };
    let mut in_ch = 64usize;
    for (stage, (&w, &nblocks)) in widths.iter().zip(blocks.iter()).enumerate() {
        for b in 0..nblocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let out_ch = w * expansion;
            let tag = format!("s{}b{}", stage + 1, b + 1);
            let identity = cur;
            let main = if bottleneck {
                let c1 = g.conv(format!("{tag}.c1"), ConvSpec::new(in_ch, w, 1, 1, 0), true, cur, rng);
                let c2 = g.conv(format!("{tag}.c2"), ConvSpec::new(w, w, 3, stride, 1), true, c1, rng);
                g.conv(format!("{tag}.c3"), ConvSpec::new(w, out_ch, 1, 1, 0), false, c2, rng)
            } else {
                let c1 = g.conv(format!("{tag}.c1"), ConvSpec::new(in_ch, w, 3, stride, 1), true, cur, rng);
                g.conv(format!("{tag}.c2"), ConvSpec::new(w, w, 3, 1, 1), false, c1, rng)
            };
            let shortcut = if stride != 1 || in_ch != out_ch {
                g.conv(
                    format!("{tag}.down"),
                    ConvSpec::new(in_ch, out_ch, 1, stride, 0),
                    false,
                    identity,
                    rng,
                )
            } else {
                identity
            };
            cur = g.push(format!("{tag}.add"), Op::Add { relu: true }, vec![main, shortcut]);
            in_ch = out_ch;
        }
    }
    let gap = g.push("gap", Op::GlobalAvgPool, vec![cur]);
    fc(&mut g, "fc", in_ch, num_classes, gap, rng);
    g
}

/// ResNeXt-101 (32×4d): bottlenecks with 32-group 3×3 convs.
pub fn resnext101(num_classes: usize, rng: &mut Rng) -> Graph {
    let blocks = [3usize, 4, 23, 3];
    let mut g = Graph::new("resnext101", (3, 224, 224));
    let c1 = g.conv("conv1", ConvSpec::new(3, 64, 7, 2, 3), true, Graph::INPUT, rng);
    let mut cur = g.push("pool1", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![c1]);
    let mut in_ch = 64usize;
    for (stage, &nblocks) in blocks.iter().enumerate() {
        // 32x4d: inner width = 128, 256, 512, 1024; out = 256..2048.
        let width = 128 << stage;
        let out_ch = 256 << stage;
        for b in 0..nblocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let tag = format!("s{}b{}", stage + 1, b + 1);
            let identity = cur;
            let c1 = g.conv(format!("{tag}.c1"), ConvSpec::new(in_ch, width, 1, 1, 0), true, cur, rng);
            let c2 = g.conv(
                format!("{tag}.c2"),
                ConvSpec::new(width, width, 3, stride, 1).grouped(32),
                true,
                c1,
                rng,
            );
            let c3 = g.conv(format!("{tag}.c3"), ConvSpec::new(width, out_ch, 1, 1, 0), false, c2, rng);
            let shortcut = if stride != 1 || in_ch != out_ch {
                g.conv(format!("{tag}.down"), ConvSpec::new(in_ch, out_ch, 1, stride, 0), false, identity, rng)
            } else {
                identity
            };
            cur = g.push(format!("{tag}.add"), Op::Add { relu: true }, vec![c3, shortcut]);
            in_ch = out_ch;
        }
    }
    let gap = g.push("gap", Op::GlobalAvgPool, vec![cur]);
    fc(&mut g, "fc", in_ch, num_classes, gap, rng);
    g
}

/// One GoogLeNet inception module.
#[allow(clippy::too_many_arguments)]
fn inception_module(
    g: &mut Graph,
    tag: &str,
    input: usize,
    in_ch: usize,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    pool_proj: usize,
    rng: &mut Rng,
) -> (usize, usize) {
    let b1 = g.conv(format!("{tag}.1x1"), ConvSpec::new(in_ch, c1, 1, 1, 0), true, input, rng);
    let b2a = g.conv(format!("{tag}.3x3r"), ConvSpec::new(in_ch, c3r, 1, 1, 0), true, input, rng);
    let b2 = g.conv(format!("{tag}.3x3"), ConvSpec::new(c3r, c3, 3, 1, 1), true, b2a, rng);
    let b3a = g.conv(format!("{tag}.5x5r"), ConvSpec::new(in_ch, c5r, 1, 1, 0), true, input, rng);
    let b3 = g.conv(format!("{tag}.5x5"), ConvSpec::new(c5r, c5, 5, 1, 2), true, b3a, rng);
    let pool = g.push(format!("{tag}.pool"), Op::MaxPool { k: 3, stride: 1, pad: 1 }, vec![input]);
    let b4 = g.conv(format!("{tag}.proj"), ConvSpec::new(in_ch, pool_proj, 1, 1, 0), true, pool, rng);
    let cat = g.push(format!("{tag}.cat"), Op::Concat, vec![b1, b2, b3, b4]);
    (cat, c1 + c3 + c5 + pool_proj)
}

/// GoogLeNet (Inception v1).
pub fn googlenet(num_classes: usize, rng: &mut Rng) -> Graph {
    let mut g = Graph::new("googlenet", (3, 224, 224));
    let c1 = g.conv("conv1", ConvSpec::new(3, 64, 7, 2, 3), true, Graph::INPUT, rng);
    let p1 = g.push("pool1", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![c1]);
    let c2 = g.conv("conv2r", ConvSpec::new(64, 64, 1, 1, 0), true, p1, rng);
    let c3 = g.conv("conv2", ConvSpec::new(64, 192, 3, 1, 1), true, c2, rng);
    let p2 = g.push("pool2", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![c3]);
    let (m3a, ch) = inception_module(&mut g, "3a", p2, 192, 64, 96, 128, 16, 32, 32, rng);
    let (m3b, ch) = inception_module(&mut g, "3b", m3a, ch, 128, 128, 192, 32, 96, 64, rng);
    let p3 = g.push("pool3", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![m3b]);
    let (m4a, ch2) = inception_module(&mut g, "4a", p3, ch, 192, 96, 208, 16, 48, 64, rng);
    let (m4b, ch2) = inception_module(&mut g, "4b", m4a, ch2, 160, 112, 224, 24, 64, 64, rng);
    let (m4c, ch2) = inception_module(&mut g, "4c", m4b, ch2, 128, 128, 256, 24, 64, 64, rng);
    let (m4d, ch2) = inception_module(&mut g, "4d", m4c, ch2, 112, 144, 288, 32, 64, 64, rng);
    let (m4e, ch2) = inception_module(&mut g, "4e", m4d, ch2, 256, 160, 320, 32, 128, 128, rng);
    let p4 = g.push("pool4", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![m4e]);
    let (m5a, ch3) = inception_module(&mut g, "5a", p4, ch2, 256, 160, 320, 32, 128, 128, rng);
    let (m5b, ch3) = inception_module(&mut g, "5b", m5a, ch3, 384, 192, 384, 48, 128, 128, rng);
    let gap = g.push("gap", Op::GlobalAvgPool, vec![m5b]);
    fc(&mut g, "fc", ch3, num_classes, gap, rng);
    g
}

/// InceptionV3 (299×299) — stem + the three inception stage families,
/// expressed with standard 1×1/3×3/5×5-equivalent factorizations.
pub fn inception_v3(num_classes: usize, rng: &mut Rng) -> Graph {
    let mut g = Graph::new("inception_v3", (3, 299, 299));
    let c1 = g.conv("stem1", ConvSpec::new(3, 32, 3, 2, 0), true, Graph::INPUT, rng);
    let c2 = g.conv("stem2", ConvSpec::new(32, 32, 3, 1, 0), true, c1, rng);
    let c3 = g.conv("stem3", ConvSpec::new(32, 64, 3, 1, 1), true, c2, rng);
    let p1 = g.push("stem.pool1", Op::MaxPool { k: 3, stride: 2, pad: 0 }, vec![c3]);
    let c4 = g.conv("stem4", ConvSpec::new(64, 80, 1, 1, 0), true, p1, rng);
    let c5 = g.conv("stem5", ConvSpec::new(80, 192, 3, 1, 0), true, c4, rng);
    let mut cur = g.push("stem.pool2", Op::MaxPool { k: 3, stride: 2, pad: 0 }, vec![c5]);
    // 3 × inception-A at 35×35 (5x5 branch factorised as two 3x3).
    let mut ch = 192usize;
    for (i, pool_ch) in [32usize, 64, 64].into_iter().enumerate() {
        let tag = format!("a{}", i + 1);
        let b1 = g.conv(format!("{tag}.1x1"), ConvSpec::new(ch, 64, 1, 1, 0), true, cur, rng);
        let b2a = g.conv(format!("{tag}.5r"), ConvSpec::new(ch, 48, 1, 1, 0), true, cur, rng);
        let b2 = g.conv(format!("{tag}.5"), ConvSpec::new(48, 64, 5, 1, 2), true, b2a, rng);
        let b3a = g.conv(format!("{tag}.3r"), ConvSpec::new(ch, 64, 1, 1, 0), true, cur, rng);
        let b3b = g.conv(format!("{tag}.3a"), ConvSpec::new(64, 96, 3, 1, 1), true, b3a, rng);
        let b3 = g.conv(format!("{tag}.3b"), ConvSpec::new(96, 96, 3, 1, 1), true, b3b, rng);
        let pool = g.push(format!("{tag}.pool"), Op::MaxPool { k: 3, stride: 1, pad: 1 }, vec![cur]);
        let b4 = g.conv(format!("{tag}.proj"), ConvSpec::new(ch, pool_ch, 1, 1, 0), true, pool, rng);
        cur = g.push(format!("{tag}.cat"), Op::Concat, vec![b1, b2, b3, b4]);
        ch = 64 + 64 + 96 + pool_ch;
    }
    // Reduction-A to 17×17.
    let r1 = g.conv("redA.3", ConvSpec::new(ch, 384, 3, 2, 0), true, cur, rng);
    let r2a = g.conv("redA.dr", ConvSpec::new(ch, 64, 1, 1, 0), true, cur, rng);
    let r2b = g.conv("redA.da", ConvSpec::new(64, 96, 3, 1, 1), true, r2a, rng);
    let r2 = g.conv("redA.db", ConvSpec::new(96, 96, 3, 2, 0), true, r2b, rng);
    let rp = g.push("redA.pool", Op::MaxPool { k: 3, stride: 2, pad: 0 }, vec![cur]);
    cur = g.push("redA.cat", Op::Concat, vec![r1, r2, rp]);
    ch = 384 + 96 + ch;
    // 4 × inception-B at 17×17 (7x7 factorised as 1x7+7x1 ≈ one 7-tap
    // pair; we model it with k=7 padding-3 separable pairs).
    for i in 0..4 {
        let tag = format!("b{}", i + 1);
        let w7 = [128usize, 160, 160, 192][i];
        let b1 = g.conv(format!("{tag}.1x1"), ConvSpec::new(ch, 192, 1, 1, 0), true, cur, rng);
        // The 1×7+7×1 factorised pair is modelled as one 7×7 (same
        // receptive field and output shape; the separable pair's two
        // smaller GEMMs are covered by other layers in the inventory).
        let b2a = g.conv(format!("{tag}.7r"), ConvSpec::new(ch, w7, 1, 1, 0), true, cur, rng);
        let b2 = g.conv(format!("{tag}.7"), ConvSpec::new(w7, 192, 7, 1, 3), true, b2a, rng);
        let pool = g.push(format!("{tag}.pool"), Op::MaxPool { k: 3, stride: 1, pad: 1 }, vec![cur]);
        let b4 = g.conv(format!("{tag}.proj"), ConvSpec::new(ch, 192, 1, 1, 0), true, pool, rng);
        cur = g.push(format!("{tag}.cat"), Op::Concat, vec![b1, b2, b4]);
        ch = 192 * 3;
    }
    // Reduction-B to 8×8 and 2 × inception-C.
    let rb1a = g.conv("redB.3r", ConvSpec::new(ch, 192, 1, 1, 0), true, cur, rng);
    let rb1 = g.conv("redB.3", ConvSpec::new(192, 320, 3, 2, 0), true, rb1a, rng);
    let rb2a = g.conv("redB.7r", ConvSpec::new(ch, 192, 1, 1, 0), true, cur, rng);
    let rb2b = g.conv("redB.7", ConvSpec::new(192, 192, 7, 1, 3), true, rb2a, rng);
    let rb2 = g.conv("redB.33", ConvSpec::new(192, 192, 3, 2, 0), true, rb2b, rng);
    let rbp = g.push("redB.pool", Op::MaxPool { k: 3, stride: 2, pad: 0 }, vec![cur]);
    cur = g.push("redB.cat", Op::Concat, vec![rb1, rb2, rbp]);
    ch = 320 + 192 + ch;
    for i in 0..2 {
        let tag = format!("c{}", i + 1);
        let b1 = g.conv(format!("{tag}.1x1"), ConvSpec::new(ch, 320, 1, 1, 0), true, cur, rng);
        let b2a = g.conv(format!("{tag}.3r"), ConvSpec::new(ch, 384, 1, 1, 0), true, cur, rng);
        let b2 = g.conv(format!("{tag}.3"), ConvSpec::new(384, 768, 3, 1, 1), true, b2a, rng);
        let b3a = g.conv(format!("{tag}.d3r"), ConvSpec::new(ch, 448, 1, 1, 0), true, cur, rng);
        let b3b = g.conv(format!("{tag}.d3a"), ConvSpec::new(448, 384, 3, 1, 1), true, b3a, rng);
        let b3 = g.conv(format!("{tag}.d3b"), ConvSpec::new(384, 768, 3, 1, 1), true, b3b, rng);
        let pool = g.push(format!("{tag}.pool"), Op::MaxPool { k: 3, stride: 1, pad: 1 }, vec![cur]);
        let b4 = g.conv(format!("{tag}.proj"), ConvSpec::new(ch, 192, 1, 1, 0), true, pool, rng);
        cur = g.push(format!("{tag}.cat"), Op::Concat, vec![b1, b2, b3, b4]);
        ch = 320 + 768 + 768 + 192;
    }
    let gap = g.push("gap", Op::GlobalAvgPool, vec![cur]);
    fc(&mut g, "fc", ch, num_classes, gap, rng);
    g
}

/// VGG16.
pub fn vgg16(num_classes: usize, rng: &mut Rng) -> Graph {
    let mut g = Graph::new("vgg16", (3, 224, 224));
    let cfg: [(usize, usize); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut cur = Graph::INPUT;
    let mut in_ch = 3usize;
    for (stage, &(width, reps)) in cfg.iter().enumerate() {
        for r in 0..reps {
            cur = g.conv(
                format!("conv{}_{}", stage + 1, r + 1),
                ConvSpec::new(in_ch, width, 3, 1, 1),
                true,
                cur,
                rng,
            );
            in_ch = width;
        }
        cur = g.push(
            format!("pool{}", stage + 1),
            Op::MaxPool { k: 2, stride: 2, pad: 0 },
            vec![cur],
        );
    }
    // Classifier: GAP-style reduction instead of the 4096-wide FCs (the
    // paper's eval is conv-bound; the FCs are latency-irrelevant here).
    let gap = g.push("gap", Op::GlobalAvgPool, vec![cur]);
    fc(&mut g, "fc", 512, num_classes, gap, rng);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_validate_and_infer() {
        for name in MODELS {
            let g = build(name, 10, 1).unwrap_or_else(|e| panic!("{name}: {e}"));
            g.infer_shapes().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.conv_count() > 0, "{name} has no convs");
        }
    }

    #[test]
    fn conv_counts_match_architectures() {
        // Known conv counts (conv layers incl. downsample projections).
        assert_eq!(build("vgg16", 10, 0).unwrap().conv_count(), 13);
        assert_eq!(build("mobilenet_v1", 10, 0).unwrap().conv_count(), 27);
        assert_eq!(build("resnet18", 10, 0).unwrap().conv_count(), 20);
        assert_eq!(build("resnet34", 10, 0).unwrap().conv_count(), 36);
        assert_eq!(build("resnet50", 10, 0).unwrap().conv_count(), 53);
        // ResNeXt101: 3+4+23+3 blocks × 3 convs + 4 downsamples + stem.
        assert_eq!(build("resnext101", 10, 0).unwrap().conv_count(), 1 + 33 * 3 + 4);
        // GoogLeNet: 3 stem + 9 modules × 6 convs.
        assert_eq!(build("googlenet", 10, 0).unwrap().conv_count(), 3 + 9 * 6);
    }

    #[test]
    fn resnet18_shapes() {
        let g = build("resnet18", 1000, 0).unwrap();
        let shapes = g.infer_shapes().unwrap();
        // Final add before gap: [1, 512, 7, 7].
        let gap_in = &shapes[shapes.len() - 3];
        assert_eq!(gap_in, &vec![1, 512, 7, 7]);
    }

    #[test]
    fn inventory_has_paper_scale_shapes() {
        let inv = layer_inventory("resnet18").unwrap();
        // Contains the classic (3136, 64, 576) GEMM.
        assert!(inv.iter().any(|l| {
            let g = l.gemm();
            (g.m, g.n, g.k) == (3136, 64, 576)
        }));
        let inv = layer_inventory("mobilenet_v1").unwrap();
        // Pointwise 1x1 @ 112×112: (12544, 64, 32).
        assert!(inv.iter().any(|l| {
            let g = l.gemm();
            (g.m, g.n, g.k) == (12544, 64, 32)
        }));
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(build("resnet99", 10, 0).is_err());
    }

    #[test]
    fn tiny_transformer_validates_and_infers() {
        let (d, heads, head_dim, _, layers, _) = TINY_TRANSFORMER_DIMS;
        assert_eq!(d, heads * head_dim);
        let g = build("tiny_transformer", 96, 1).unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output], vec![1, 96], "logits over the vocab");
        let attn = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Attention { .. }))
            .count();
        assert_eq!(attn, layers, "one attention node per layer");
        let quant_fcs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Fc { quant: true, .. }))
            .count();
        // embed + per-layer (q, k, v, proj, ff1, ff2).
        assert_eq!(quant_fcs, 1 + 6 * layers);
        assert_eq!(g.conv_count(), 0, "the decode workload is FC/attention only");
        assert!(g.conv_params() > 0, "FC weights count as parameters");
    }
}
