//! Toolchain probe for the AVX-512 kernels.
//!
//! The AVX-512 intrinsics and `#[target_feature(enable = "avx512…")]`
//! are stable from Rust 1.89. The crate must keep building on older
//! stable toolchains (the build is fully offline and cannot pin a
//! toolchain), so the 512-bit micro-kernels are compiled only when the
//! active `rustc` is new enough, behind the custom `deepgemm_avx512`
//! cfg this script emits. Runtime feature detection still gates every
//! call — the cfg only decides whether the code *exists*.

use std::process::Command;

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let version = Command::new(&rustc)
        .arg("--version")
        .output()
        .ok()
        .map(|o| String::from_utf8_lossy(&o.stdout).into_owned())
        .unwrap_or_default();
    if let Some((major, minor)) = parse_version(&version) {
        // `rustc-check-cfg` (so `deepgemm_avx512` is a *known* cfg under
        // -D warnings) uses the `cargo::` directive syntax, itself only
        // understood by Cargo ≥ 1.77 — every toolchain that needs the
        // check-cfg declaration also understands the directive.
        if (major, minor) >= (1, 80) {
            println!("cargo::rustc-check-cfg=cfg(deepgemm_avx512)");
        }
        if (major, minor) >= (1, 89) {
            println!("cargo:rustc-cfg=deepgemm_avx512");
        }
    }
}

/// Parse "rustc 1.89.0 (…)" (or a nightly/beta variant) into (1, 89).
fn parse_version(version: &str) -> Option<(u32, u32)> {
    let semver = version.split_whitespace().nth(1)?;
    let mut parts = semver.split(|c: char| !c.is_ascii_digit());
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}
