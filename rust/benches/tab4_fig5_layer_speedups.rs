//! Paper Tab. 4 + Fig. 5: per-layer speedups of DeepGEMM (LUT-16 2-bit)
//! over the QNNPACK-style INT8 baseline, across the conv layer shapes of
//! MobileNetV1 / ResNet18 / ResNet34 / ResNet50.
//!
//! Paper reference geomeans: 1.74× / 1.64× / 1.67× / 1.57× (avg 1.66×).
//! Expected shape on this testbed: LUT-16 > 1× everywhere except very
//! small K, gap growing with K (the kernel is vectorized along K).
//!
//! With `--autotune quick|full` (or `AUTOTUNE=quick`), a third lut16
//! column measures the *autotuned* cache-block shape next to the
//! default one; the chosen MC/NC/KC per layer is printed as a note and
//! the JSON artifacts get an `_tuned` suffix. The tuned shape must beat
//! or match the default (it is always in the candidate grid), modulo
//! measurement noise — see docs/TUNING.md.
//!
//! A second per-model table (`fig5_<model>_fused` artifacts) reports
//! the implicit-GEMM memory effect per layer: the bytes of the M×K
//! im2col code matrix the pre-fusion pipeline materialized (now
//! eliminated — see docs/FUSION.md), the K-byte gather row that
//! replaced it, and the packed activation operand (unchanged by the
//! fusion). Tuning is unaffected: tune keys and the measured GEMM are
//! identical in both pipelines.

use deepgemm::bench::{autotune_mode, support, threads_axis, BenchOpts, Table};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend, K_BLOCK};
use deepgemm::util::{align_up, geomean};

fn main() {
    let opts = BenchOpts {
        warmup: 0.05,
        measure: 0.35,
        max_samples: 40,
        ..BenchOpts::from_env()
    };
    // Both engines execute tiled plans; pin to one worker (the paper's
    // single-core setting) unless --threads overrides it. This bench
    // has no thread axis — a multi-value list collapses to its maximum,
    // loudly.
    let taxis = threads_axis(&[1]);
    let nt = *taxis.last().unwrap();
    if taxis.len() > 1 {
        eprintln!("[tab4] no thread axis here; measuring at the max, --threads {nt}");
    }
    tile::set_default_threads(nt);
    let mode = autotune_mode();
    if mode.is_on() {
        eprintln!("[tab4] autotune {}: adding a tuned lut16 column", mode.name());
    }
    let models = [
        ("mobilenet_v1", 1.74),
        ("resnet18", 1.64),
        ("resnet34", 1.67),
        ("resnet50", 1.57),
    ];
    let mut summary = Table::new(
        "Tab 4 — geomean conv-layer speedup over INT8 (paper in parens)",
        &["geomean speedup", "paper"],
    );
    let mut all_geo = Vec::new();
    for (model, paper) in models {
        let layers = support::model_gemms(model).expect("model inventory");
        let mut cols = vec!["M", "N", "K", "int8 ms", "lut16 ms", "speedup"];
        if mode.is_on() {
            cols.push("tuned ms");
            cols.push("tuned spdup");
        }
        let mut fig5 = Table::new(
            format!("Fig 5 — {model}: per-layer latency & speedup"),
            &cols,
        );
        let mut speedups = Vec::new();
        let mut tuned_vs_default = Vec::new();
        for (name, size) in &layers {
            let t_int8 = support::time_backend(Backend::Int8, *size, &opts);
            let t_lut = support::time_backend(Backend::Lut16(Scheme::D), *size, &opts);
            let sp = t_int8 / t_lut;
            speedups.push(sp);
            let mut values = vec![
                size.m as f64,
                size.n as f64,
                size.k as f64,
                t_int8 * 1e3,
                t_lut * 1e3,
                sp,
            ];
            if mode.is_on() {
                let (t_tuned, outcome) =
                    support::time_backend_tuned(Backend::Lut16(Scheme::D), *size, &opts, mode);
                values.push(t_tuned * 1e3);
                values.push(t_int8 / t_tuned);
                tuned_vs_default.push(t_lut / t_tuned);
                if let Some(o) = outcome {
                    fig5.note(format!("{name}: {}", o.describe()));
                }
            }
            fig5.row(format!("{name} ({},{},{})", size.m, size.n, size.k), values);
        }
        let geo = geomean(&speedups);
        all_geo.push(geo);
        fig5.note(format!("geomean speedup = {geo:.3} (paper: {paper})"));
        if mode.is_on() {
            fig5.note(format!(
                "geomean tuned-vs-default lut16 = {:.3} (>= 1 means the autotuned shape wins)",
                geomean(&tuned_vs_default)
            ));
        }
        print!("{}", fig5.render());
        // Bare artifact names stay reserved for the single-thread,
        // default-shape paper-setting numbers (same convention as fig7).
        let mut file =
            if nt == 1 { format!("fig5_{model}") } else { format!("fig5_{model}_t{nt}") };
        if mode.is_on() {
            file.push_str("_tuned");
        }
        fig5.write_json(&file).expect("write json");
        summary.row(model, vec![geo, paper]);

        // Implicit-GEMM memory effect: what the kill-im2col fusion
        // removes per layer. The materialized pipeline allocated an M×K
        // u8 code matrix per conv; the fused pipeline gathers one
        // K-byte row at a time while packing (docs/FUSION.md).
        let mut fused = Table::new(
            format!("Fig 5 (fused) — {model}: per-layer im2col bytes eliminated"),
            &["M", "K", "im2col KiB eliminated", "gather row B", "packed act KiB"],
        );
        let a_layout = Scheme::D.a_layout();
        let mut total_elim = 0usize;
        let mut total_packed = 0usize;
        for (name, size) in &layers {
            let elim = size.m * size.k; // one u8 code per (m, k)
            let packed = size.m * a_layout.bytes_for(align_up(size.k.max(1), K_BLOCK));
            total_elim += elim;
            total_packed += packed;
            fused.row(
                format!("{name} ({},{},{})", size.m, size.n, size.k),
                vec![
                    size.m as f64,
                    size.k as f64,
                    elim as f64 / 1024.0,
                    size.k as f64,
                    packed as f64 / 1024.0,
                ],
            );
        }
        fused.note(format!(
            "total eliminated = {:.1} KiB of materialized im2col; steady-state gather \
             scratch = max-K row ({} B); packed operand ({:.1} KiB, lut16-d layout) is \
             unchanged by the fusion",
            total_elim as f64 / 1024.0,
            layers.iter().map(|(_, s)| s.k).max().unwrap_or(0),
            total_packed as f64 / 1024.0
        ));
        print!("{}", fused.render());
        fused.write_json(&format!("fig5_{model}_fused")).expect("write json");
    }
    summary.row("average", vec![geomean(&all_geo), 1.66]);
    summary.note("backend lut16-d (scheme d) vs QNNPACK-style int8 (unpack+pmaddwd)");
    summary.note(format!("both tiled, at {nt} worker thread(s) (paper setting: 1)"));
    if mode.is_on() {
        summary.note(format!(
            "autotune {}: chosen shapes in the fig5 notes above",
            mode.name()
        ));
    }
    print!("{}", summary.render());
    let mut file =
        if nt == 1 { "tab4_geomeans".to_string() } else { format!("tab4_geomeans_t{nt}") };
    if mode.is_on() {
        file.push_str("_tuned");
    }
    summary.write_json(&file).expect("write json");
}
