//! Paper §5.3: comparison with ultra low-bit methods (bit-serial,
//! ULPPACK) and the flexibility claims:
//!   1. per-layer speedups over INT8 on the MobileNetV1 conv shapes —
//!      paper cites ULPPACK geomean 1.77× vs DeepGEMM 1.74×;
//!   2. signed vs unsigned LUT-16 latency is *identical* (bipolar support
//!      for free), unlike ULPPACK (unsigned-only + fixup) and bit-serial
//!      (extra popcounts for bipolar);
//!   3. float-entry LUT (non-uniform quantization) — the capability the
//!      integer-only baselines cannot offer at all.

use deepgemm::bench::{support, BenchOpts, Table};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend, GemmSize};
use deepgemm::quant::{IntCodebook, Lut16};
use deepgemm::util::geomean;

fn main() {
    let opts = BenchOpts {
        warmup: 0.05,
        measure: 0.3,
        max_samples: 40,
        ..BenchOpts::from_env()
    };
    // Bit-serial and ULPPACK remain row-streaming single-thread; pin
    // the tiled backends to one worker so the §5.3 race stays fair.
    tile::set_default_threads(1);
    // (1) method comparison on MobileNetV1 shapes.
    let layers = support::model_gemms("mobilenet_v1").expect("inventory");
    let methods = [
        ("lut16-d (DeepGEMM)", Backend::Lut16(Scheme::D)),
        ("lut65k (DeepGEMM)", Backend::Lut65k),
        ("ulppack", Backend::UlpPack),
        ("bitserial", Backend::BitSerial),
    ];
    let mut t = Table::new(
        "§5.3 — geomean speedup over INT8 on MobileNetV1 conv shapes",
        &["geomean speedup", "paper"],
    );
    let paper_ref = [1.74, f64::NAN, 1.77, f64::NAN];
    for ((name, backend), paper) in methods.iter().zip(paper_ref) {
        let mut sps = Vec::new();
        for (_, size) in &layers {
            let t_int8 = support::time_backend(Backend::Int8, *size, &opts);
            let t_m = support::time_backend(*backend, *size, &opts);
            sps.push(t_int8 / t_m);
        }
        t.row(*name, vec![geomean(&sps), paper]);
    }
    t.note("paper: ULPPACK 1.77x vs DeepGEMM 1.74x — close race expected");
    print!("{}", t.render());
    t.write_json("sec53_methods").expect("json");

    // (2) signed vs unsigned LUT latency — must be identical (the kernel
    // only sees a different 16-byte table).
    let size = GemmSize::new(256, 64, 1152);
    let mut t2 = Table::new(
        "§5.3 — LUT-16 latency vs operand signedness (identical by construction)",
        &["gemm ms"],
    );
    for (label, w_signed, a_signed) in [
        ("unipolar w / unipolar a", false, false),
        ("bipolar w / unipolar a", true, false),
        ("bipolar w / bipolar a", true, true),
    ] {
        // Build the problem manually so only the LUT differs.
        use deepgemm::kernels::pack;
        use deepgemm::kernels::{lut16, CodeMat};
        let wcb = if w_signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
        let acb = if a_signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
        let a = CodeMat::random(size.m, size.k, 2, 5);
        let w = CodeMat::random(size.n, size.k, 2, 6);
        let lut = Lut16::build(&wcb, &acb);
        let ap = pack::pack_activations(&a, Scheme::D);
        let wp = pack::pack_weights(&w, Scheme::D);
        let mut out = vec![0i32; size.m * size.n];
        let secs = deepgemm::bench::bench(label, &opts, || {
            lut16::gemm(&ap, &wp, &lut, Scheme::D, &mut out);
            std::hint::black_box(&out);
        })
        .secs();
        t2.row(label, vec![secs * 1e3]);
    }
    t2.note("ULPPACK needs pre/post fixup ops for signed inputs; bit-serial needs extra popcounts");
    print!("{}", t2.render());
    t2.write_json("sec53_signedness").expect("json");

    // Spread check: signedness must not change latency beyond noise.
    let times: Vec<f64> = t2.rows.iter().map(|(_, v)| v[0]).collect();
    let spread = (times.iter().cloned().fold(f64::MIN, f64::max)
        - times.iter().cloned().fold(f64::MAX, f64::min))
        / times[0];
    println!("signedness latency spread: {:.1}% (expect < 10%)", spread * 100.0);

    // (3) non-uniform (float LUT) — integer baselines cannot do this.
    let t_f32lut = support::time_backend(Backend::Lut16F32, size, &opts);
    let t_int = support::time_backend(Backend::Lut16(Scheme::D), size, &opts);
    let mut t3 = Table::new(
        "§5.3 — non-uniform quantization via f32-entry LUT",
        &["gemm ms", "vs int-lut"],
    );
    t3.row("lut16-d (int entries)", vec![t_int * 1e3, 1.0]);
    t3.row("lut16-f32 (non-uniform)", vec![t_f32lut * 1e3, t_f32lut / t_int]);
    t3.note("bit-serial / ULPPACK: integer-only, no non-uniform support (paper §5.3)");
    print!("{}", t3.render());
    t3.write_json("sec53_nonuniform").expect("json");
}
