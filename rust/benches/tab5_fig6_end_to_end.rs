//! Paper Tab. 5 + Fig. 6: end-to-end model inference speedups over the
//! INT8 baseline (all conv layers quantized; includes activation
//! quantize/pack/dequant overheads, exactly as §5.2 measures).
//!
//! Paper reference: ResNet18 1.62×, ResNet34 1.68×, ResNet50 1.59×,
//! ResNeXt101 1.50×, GoogleNet 1.50×, InceptionV3 1.58× (avg 1.58×).
//! Expected shape: e2e gains smaller than per-layer gains (overheads),
//! biggest on ResNets where conv GEMMs dominate.
//!
//! Full-size ImageNet graphs at 224²/299² are heavy on one debug core;
//! DEEPGEMM_BENCH_QUICK=1 restricts to ResNet18 + GoogleNet.
//!
//! `--threads N[,M,...]` (after `--` under `cargo bench`) adds a
//! thread-count axis: one row per (model, threads) pair. *Both* engines
//! execute tiled `GemmPlan`s at the given worker count — the speedup
//! column is an apples-to-apples tiled-vs-tiled comparison at every
//! point on the axis, exactly as the paper's single-core numbers are.
//!
//! `--autotune quick|full` adds the batched tuned-vs-mistuned columns:
//! a fused batch of 8 images (M = 8·oh·ow per layer) is served once by
//! a model whose block shapes were tuned only at the per-image M (the
//! pre-bucketing serving bug: every batched GEMM runs a shape measured
//! for the wrong M) and once by a batch-aware model tuned over the
//! M-bucket grid {1,2,4,8}·per-image-M — `b8 speedup` ≥ 1.0 means the
//! bucket-matched shapes win on the serving hot path. Tuned runs write
//! `_tuned`-suffixed artifacts so the paper-setting files are never
//! clobbered.

use deepgemm::bench::{autotune_mode, threads_axis, Table};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;
use deepgemm::util::geomean;
use std::time::Instant;

/// Fused batch size for the tuned-vs-mistuned comparison (matches the
/// default M-bucket grid's top bucket).
const BATCH: usize = 8;

fn run_model(model: &CompiledModel, xs: &[Tensor], iters: usize) -> f64 {
    let mut prof = StageProfile::new();
    // Reuse one ExecCtx across iterations (the serving steady state):
    // the warmup run grows the planned arena + scratch, the timed runs
    // perform no allocation in the conv pipeline.
    let mut ctx = model.new_ctx();
    model.forward_batch_with(xs, &mut ctx, &mut prof).expect("warmup");
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        model.forward_batch_with(xs, &mut ctx, &mut prof).expect("forward");
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let quick = std::env::var("DEEPGEMM_BENCH_QUICK").ok().as_deref() == Some("1");
    let mode = autotune_mode();
    let models: Vec<(&str, f64)> = if quick {
        vec![("resnet18", 1.62), ("googlenet", 1.50)]
    } else {
        vec![
            ("resnet18", 1.62),
            ("resnet34", 1.68),
            ("resnet50", 1.59),
            ("resnext101", 1.50),
            ("googlenet", 1.50),
            ("inception_v3", 1.58),
        ]
    };
    let iters = if quick { 1 } else { 2 };
    let threads = threads_axis(&[1]);
    let mut t = Table::new(
        "Tab 5 / Fig 6 — end-to-end speedup over INT8",
        &[
            "threads",
            "int8 ms",
            "lut16-d ms",
            "speedup",
            "b8 mistuned ms",
            "b8 tuned ms",
            "b8 speedup",
            "paper",
        ],
    );
    let mut sps = Vec::new();
    let mut bsps = Vec::new();
    for (name, paper) in &models {
        eprintln!("[e2e] building {name}...");
        let graph = zoo::build(name, 1000, 0).expect("build");
        let (c, h, w) = graph.input_chw;
        let x = Tensor::random(&[1, c, h, w], 42, -1.0, 1.0);
        let calib = [x.clone()];
        let xs = std::slice::from_ref(&x);
        let xs_b: Vec<Tensor> =
            (0..BATCH).map(|b| Tensor::random(&[1, c, h, w], 43 + b as u64, -1.0, 1.0)).collect();
        eprintln!("[e2e] compiling {name} for int8...");
        let m_int8 = CompiledModel::compile(graph.clone(), Backend::Int8, &calib).expect("int8");
        eprintln!("[e2e] compiling {name} for lut16-d...");
        let m_lut =
            CompiledModel::compile(graph.clone(), Backend::Lut16(Scheme::D), &calib).expect("lut");
        for &nt in &threads {
            tile::set_default_threads(nt);
            let t_int8 = run_model(&m_int8, xs, iters);
            let t_lut = run_model(&m_lut, xs, iters);
            let sp = t_int8 / t_lut;
            // Batched tuned-vs-mistuned (only meaningful with tuning on).
            // Compiled inside the thread loop: tuning keys include the
            // resolved worker count.
            let (tb_mist, tb_tuned, sp_b) = if mode.is_on() {
                let assign =
                    |_: usize, _: &deepgemm::nn::ConvSpec| -> Option<Backend> { None };
                eprintln!(
                    "[e2e] tuning {name} t={nt} (per-image M only — mistuned for b{BATCH})..."
                );
                let m_mist = CompiledModel::compile_tuned_batched(
                    graph.clone(),
                    Backend::Lut16(Scheme::D),
                    &calib,
                    &assign,
                    mode,
                    1,
                )
                .expect("mistuned compile");
                eprintln!("[e2e] tuning {name} t={nt} (M buckets up to b{BATCH})...");
                let m_tuned = CompiledModel::compile_tuned_batched(
                    graph.clone(),
                    Backend::Lut16(Scheme::D),
                    &calib,
                    &assign,
                    mode,
                    BATCH,
                )
                .expect("bucketed compile");
                let tm = run_model(&m_mist, &xs_b, iters);
                let tt = run_model(&m_tuned, &xs_b, iters);
                (tm * 1e3, tt * 1e3, tm / tt)
            } else {
                (f64::NAN, f64::NAN, f64::NAN)
            };
            if nt == *threads.iter().max().unwrap() {
                sps.push(sp);
                if sp_b.is_finite() {
                    bsps.push(sp_b);
                }
            }
            eprintln!(
                "[e2e] {name} t={nt}: int8 {:.1} ms, lut {:.1} ms, speedup {sp:.3}, \
                 b{BATCH} mistuned {tb_mist:.1} ms vs tuned {tb_tuned:.1} ms ({sp_b:.3}x)",
                t_int8 * 1e3,
                t_lut * 1e3
            );
            // Bare model name for the single-thread row — keeps the
            // default run's labels comparable with older artifacts.
            let label =
                if nt == 1 { (*name).to_string() } else { format!("{name}@t{nt}") };
            t.row(
                label,
                vec![nt as f64, t_int8 * 1e3, t_lut * 1e3, sp, tb_mist, tb_tuned, sp_b, *paper],
            );
        }
    }
    let b_avg = if bsps.is_empty() { f64::NAN } else { geomean(&bsps) };
    t.row(
        "average",
        vec![f64::NAN, f64::NAN, f64::NAN, geomean(&sps), f64::NAN, f64::NAN, b_avg, 1.58],
    );
    t.note("depthwise convs run the same direct path in both engines; non-conv ops identical");
    t.note("both engines execute tiled GemmPlans at the row's thread count (tiled-vs-tiled)");
    t.note(format!(
        "kernel ISA arm: {} (override with --isa / DEEPGEMM_ISA; see docs/SIMD.md)",
        deepgemm::kernels::simd::active().name()
    ));
    t.note(
        "b8 columns (autotune on): one fused batch of 8 served on per-image-M shapes \
         (mistuned) vs M-bucket shapes (tuned)",
    );
    print!("{}", t.render());
    let artifact = if mode.is_on() {
        "tab5_fig6_end_to_end_tuned"
    } else {
        "tab5_fig6_end_to_end"
    };
    t.write_json(artifact).expect("write json");
}
