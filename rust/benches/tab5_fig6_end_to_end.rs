//! Paper Tab. 5 + Fig. 6: end-to-end model inference speedups over the
//! INT8 baseline (all conv layers quantized; includes activation
//! quantize/pack/dequant overheads, exactly as §5.2 measures).
//!
//! Paper reference: ResNet18 1.62×, ResNet34 1.68×, ResNet50 1.59×,
//! ResNeXt101 1.50×, GoogleNet 1.50×, InceptionV3 1.58× (avg 1.58×).
//! Expected shape: e2e gains smaller than per-layer gains (overheads),
//! biggest on ResNets where conv GEMMs dominate.
//!
//! Full-size ImageNet graphs at 224²/299² are heavy on one debug core;
//! DEEPGEMM_BENCH_QUICK=1 restricts to ResNet18 + GoogleNet.
//!
//! `--threads N[,M,...]` (after `--` under `cargo bench`) adds a
//! thread-count axis: one row per (model, threads) pair. *Both* engines
//! execute tiled `GemmPlan`s at the given worker count — the speedup
//! column is an apples-to-apples tiled-vs-tiled comparison at every
//! point on the axis, exactly as the paper's single-core numbers are.

use deepgemm::bench::{threads_axis, Table};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;
use deepgemm::util::geomean;
use std::time::Instant;

fn run_model(model: &CompiledModel, x: &Tensor, iters: usize) -> f64 {
    let mut prof = StageProfile::new();
    // Reuse one ExecCtx across iterations (the serving steady state):
    // the warmup run grows the planned arena + scratch, the timed runs
    // perform no allocation in the conv pipeline.
    let mut ctx = model.new_ctx();
    let xs = std::slice::from_ref(x);
    model.forward_batch_with(xs, &mut ctx, &mut prof).expect("warmup");
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        model.forward_batch_with(xs, &mut ctx, &mut prof).expect("forward");
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let quick = std::env::var("DEEPGEMM_BENCH_QUICK").ok().as_deref() == Some("1");
    let models: Vec<(&str, f64)> = if quick {
        vec![("resnet18", 1.62), ("googlenet", 1.50)]
    } else {
        vec![
            ("resnet18", 1.62),
            ("resnet34", 1.68),
            ("resnet50", 1.59),
            ("resnext101", 1.50),
            ("googlenet", 1.50),
            ("inception_v3", 1.58),
        ]
    };
    let iters = if quick { 1 } else { 2 };
    let threads = threads_axis(&[1]);
    let mut t = Table::new(
        "Tab 5 / Fig 6 — end-to-end speedup over INT8",
        &["threads", "int8 ms", "lut16-d ms", "speedup", "paper"],
    );
    let mut sps = Vec::new();
    for (name, paper) in &models {
        eprintln!("[e2e] building {name}...");
        let graph = zoo::build(name, 1000, 0).expect("build");
        let (c, h, w) = graph.input_chw;
        let x = Tensor::random(&[1, c, h, w], 42, -1.0, 1.0);
        let calib = [x.clone()];
        eprintln!("[e2e] compiling {name} for int8...");
        let m_int8 = CompiledModel::compile(graph.clone(), Backend::Int8, &calib).expect("int8");
        eprintln!("[e2e] compiling {name} for lut16-d...");
        let m_lut =
            CompiledModel::compile(graph, Backend::Lut16(Scheme::D), &calib).expect("lut");
        for &nt in &threads {
            tile::set_default_threads(nt);
            let t_int8 = run_model(&m_int8, &x, iters);
            let t_lut = run_model(&m_lut, &x, iters);
            let sp = t_int8 / t_lut;
            if nt == *threads.iter().max().unwrap() {
                sps.push(sp);
            }
            eprintln!(
                "[e2e] {name} t={nt}: int8 {:.1} ms, lut {:.1} ms, speedup {sp:.3}",
                t_int8 * 1e3,
                t_lut * 1e3
            );
            // Bare model name for the single-thread row — keeps the
            // default run's labels comparable with older artifacts.
            let label =
                if nt == 1 { (*name).to_string() } else { format!("{name}@t{nt}") };
            t.row(label, vec![nt as f64, t_int8 * 1e3, t_lut * 1e3, sp, *paper]);
        }
    }
    t.row("average", vec![f64::NAN, f64::NAN, f64::NAN, geomean(&sps), 1.58]);
    t.note("depthwise convs run the same direct path in both engines; non-conv ops identical");
    t.note("both engines execute tiled GemmPlans at the row's thread count (tiled-vs-tiled)");
    print!("{}", t.render());
    t.write_json("tab5_fig6_end_to_end").expect("write json");
}
