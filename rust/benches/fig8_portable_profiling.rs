//! Paper Fig. 8: kernel profiling on the Arm platform (Raspberry Pi 4B),
//! where Neon's lack of a 128-bit table-lookup instruction makes the LUT
//! approach uncompetitive.
//!
//! Offline substitution (DESIGN.md §6.4): the [`Backend::Portable`]
//! scalar kernel plays the "no fast byte-shuffle" role on the same
//! machine. Expected shape: Lut-Conv fraction balloons vs the AVX2
//! profile, and the portable LUT kernel *loses* to INT8 — exactly the
//! paper's Arm story.

use deepgemm::bench::{support, BenchOpts, Table};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend, GemmSize};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::{Stage, StageProfile};
use deepgemm::util::geomean;

fn main() {
    let opts = BenchOpts {
        warmup: 0.05,
        measure: 0.3,
        max_samples: 30,
        ..BenchOpts::from_env()
    };
    // The portable kernel is single-threaded scalar; pin its tiled
    // competitors to one worker so the comparison stays one-core.
    tile::set_default_threads(1);
    // Stage profile with the portable kernel (small_cnn keeps the scalar
    // path tractable — the RPi in the paper is ~20x slower than its x86).
    let graph = zoo::build("small_cnn", 10, 0).expect("build");
    let x = Tensor::random(&[1, 3, 32, 32], 3, -1.0, 1.0);
    let model =
        CompiledModel::compile(graph, Backend::Portable, &[x.clone()]).expect("compile");
    let mut prof = StageProfile::new();
    // Serving-style context reuse: warmup grows the buffers once.
    let mut ctx = model.new_ctx();
    let xs = std::slice::from_ref(&x);
    model.forward_batch_with(xs, &mut ctx, &mut StageProfile::new()).expect("warmup");
    for _ in 0..5 {
        model.forward_batch_with(xs, &mut ctx, &mut prof).expect("fwd");
    }
    let mut t = Table::new(
        "Fig 8 — stage breakdown with the portable (no-byte-shuffle) kernel",
        &["ms", "% of total"],
    );
    let total = prof.total();
    for st in Stage::ALL {
        if prof.calls(st) > 0 {
            t.row(st.name(), vec![prof.secs(st) * 1e3 / 5.0, 100.0 * prof.secs(st) / total]);
        }
    }
    t.note("portable scalar LUT = the 'Arm without tbl' stand-in (DESIGN.md §6.4)");
    print!("{}", t.render());
    t.write_json("fig8_stages").expect("json");

    // Portable LUT vs INT8 on a few layer shapes: the LUT advantage must
    // evaporate without a vector table lookup.
    let shapes = [
        GemmSize::new(196, 64, 576),
        GemmSize::new(784, 32, 288),
        GemmSize::new(49, 128, 1152),
    ];
    let mut t2 = Table::new(
        "Fig 8 (companion) — portable LUT vs INT8 per-layer speedup",
        &["int8 ms", "portable-lut ms", "speedup"],
    );
    let mut sps = Vec::new();
    for size in shapes {
        let t_int8 = support::time_backend(Backend::Int8, size, &opts);
        let t_port = support::time_backend(Backend::Portable, size, &opts);
        sps.push(t_int8 / t_port);
        t2.row(
            format!("({},{},{})", size.m, size.n, size.k),
            vec![t_int8 * 1e3, t_port * 1e3, t_int8 / t_port],
        );
    }
    let geo = geomean(&sps);
    t2.note(format!(
        "geomean {geo:.3} — expected < 1 (vs AVX2 lut16-d > 1): no shuffle, no win"
    ));
    print!("{}", t2.render());
    t2.write_json("fig8_portable_vs_int8").expect("json");

    // Sanity on the expected shape: vectorized lut16-d must beat the
    // portable kernel by a wide margin.
    let size = GemmSize::new(196, 64, 576);
    let t_simd = support::time_backend(Backend::Lut16(Scheme::D), size, &opts);
    let t_port = support::time_backend(Backend::Portable, size, &opts);
    println!(
        "\nvector/scalar LUT ratio at (196,64,576): {:.1}x (paper's x86-vs-Arm gap analogue)",
        t_port / t_simd
    );
    assert!(t_port > t_simd, "portable must be slower than AVX2 lut16");
}
