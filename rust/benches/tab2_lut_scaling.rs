//! Paper Tab. 2: scaling LUT-16 to larger bitwidths (2/3/4-bit) — table
//! metadata (index width, entries, size, AVX2 registers, L1 fit) plus the
//! *measured* latency cost of the bigger tables on a fixed GEMM shape.
//!
//! Expected shape: all three fit in L1; LUT access cost rises modestly
//! from 2-bit (1 shuffle) to 3-bit (2 tables + blends) to 4-bit (16
//! tables + compare/mask).

use deepgemm::bench::{support, threads_axis, BenchOpts, Table};
use deepgemm::kernels::{tile, Backend, GemmSize};
use deepgemm::quant::{IntCodebook, Lut16};

fn main() {
    let opts = BenchOpts::from_env();
    // Kernel-level comparison: single-core like the paper unless a
    // --threads override is given (all backends run tiled plans). This
    // bench has no thread axis — a multi-value list collapses to its
    // maximum, loudly.
    let taxis = threads_axis(&[1]);
    let nt = *taxis.last().unwrap();
    if taxis.len() > 1 {
        eprintln!("[tab2] no thread axis here; measuring at the max, --threads {nt}");
    }
    tile::set_default_threads(nt);
    let size = GemmSize::new(128, 64, 576);
    let mut t = Table::new(
        "Tab 2 — scaling LUT-16 to larger bitwidths",
        &[
            "index bits",
            "LUT entries",
            "LUT bits",
            "AVX2 regs",
            "fits L1 (32KB)",
            "gemm ms",
            "vs 2-bit",
        ],
    );
    let mut base = 0.0;
    for bits in [2u32, 3, 4] {
        let cb = IntCodebook::signed(bits);
        let lut = Lut16::build(&cb, &IntCodebook::unsigned(bits));
        let backend = if bits == 2 {
            Backend::Lut16(deepgemm::kernels::pack::Scheme::D)
        } else {
            Backend::LutWide(bits)
        };
        let secs = support::time_backend(backend, size, &opts);
        if bits == 2 {
            base = secs;
        }
        t.row(
            format!("{bits}-bit"),
            vec![
                (2 * bits) as f64,
                lut.entries() as f64,
                lut.size_bits() as f64,
                lut.avx2_registers() as f64,
                (lut.size_bits() / 8 <= 32 * 1024) as u8 as f64,
                secs * 1e3,
                secs / base,
            ],
        );
    }
    t.note(format!(
        "paper Tab.2: entries 16/64/256, size 128/512/2048 bits, regs 1/2/8, all fit L1; gemm at (M,N,K)=({},{},{})",
        size.m, size.n, size.k
    ));
    t.note(format!("tiled plans at {nt} worker thread(s) (paper setting: 1)"));
    print!("{}", t.render());
    // Bare artifact name stays reserved for the single-thread
    // paper-setting numbers (same convention as fig7).
    let file =
        if nt == 1 { "tab2_lut_scaling".to_string() } else { format!("tab2_lut_scaling_t{nt}") };
    t.write_json(&file).expect("write json");
}
