//! Paper Tab. 2: scaling LUT-16 to larger bitwidths (2/3/4-bit) — table
//! metadata (index width, entries, size, AVX2 registers, L1 fit) plus the
//! *measured* latency cost of the bigger tables on a fixed GEMM shape.
//!
//! Expected shape: all three fit in L1; LUT access cost rises modestly
//! from 2-bit (1 shuffle) to 3-bit (2 tables + blends) to 4-bit (16
//! tables + compare/mask).

use deepgemm::bench::{support, BenchOpts, Table};
use deepgemm::kernels::{Backend, GemmSize};
use deepgemm::quant::{IntCodebook, Lut16};

fn main() {
    let opts = BenchOpts::from_env();
    let size = GemmSize::new(128, 64, 576);
    let mut t = Table::new(
        "Tab 2 — scaling LUT-16 to larger bitwidths",
        &[
            "index bits",
            "LUT entries",
            "LUT bits",
            "AVX2 regs",
            "fits L1 (32KB)",
            "gemm ms",
            "vs 2-bit",
        ],
    );
    let mut base = 0.0;
    for bits in [2u32, 3, 4] {
        let cb = IntCodebook::signed(bits);
        let lut = Lut16::build(&cb, &IntCodebook::unsigned(bits));
        let backend = if bits == 2 {
            Backend::Lut16(deepgemm::kernels::pack::Scheme::D)
        } else {
            Backend::LutWide(bits)
        };
        let secs = support::time_backend(backend, size, &opts);
        if bits == 2 {
            base = secs;
        }
        t.row(
            format!("{bits}-bit"),
            vec![
                (2 * bits) as f64,
                lut.entries() as f64,
                lut.size_bits() as f64,
                lut.avx2_registers() as f64,
                (lut.size_bits() / 8 <= 32 * 1024) as u8 as f64,
                secs * 1e3,
                secs / base,
            ],
        );
    }
    t.note(format!(
        "paper Tab.2: entries 16/64/256, size 128/512/2048 bits, regs 1/2/8, all fit L1; gemm at (M,N,K)=({},{},{})",
        size.m, size.n, size.k
    ));
    print!("{}", t.render());
    t.write_json("tab2_lut_scaling").expect("write json");
}
