//! Decode throughput workload: tokens/sec for the KV-cached
//! `tiny_transformer` per backend × supported ISA arm.
//!
//! Two phases per row, both running the engine's per-token decode path
//! (each forward consumes one token embedding and appends one KV
//! position — there is no batched prefill GEMM in this engine, so
//! "prefill" measures the same path over the prompt):
//!
//! - **prefill**: the first P positions of a fresh context,
//! - **decode**: the next G positions on the now-warm context — the
//!   steady state, where every quantized projection is a per-image
//!   M = 1 GEMM routed down the GEMV row path.
//!
//! The bench asserts the GEMV path was actually selected (process-wide
//! counters in `kernels::tile`) and finishes with an end-to-end oracle
//! check: the same model forced through the register-tiled grid driver
//! (`CompiledModel::set_gemv(false)`) must produce bit-identical
//! logits. `DEEPGEMM_BENCH_QUICK=1` shrinks P/G and the backend set.

use deepgemm::bench::Table;
use deepgemm::engine::{CompiledModel, ExecCtx};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::simd::{self, Isa};
use deepgemm::kernels::{tile, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;
use std::time::Instant;

const VOCAB: usize = 16;

fn token(t: u64) -> Tensor {
    let d = zoo::TINY_TRANSFORMER_DIMS.0;
    Tensor::random(&[1, d, 1, 1], 0xBE9C4 + t, -1.0, 1.0)
}

/// Decode positions `[from, to)` on `ctx`, returning (seconds, last
/// logits).
fn run_span(
    model: &CompiledModel,
    ctx: &mut ExecCtx,
    from: u64,
    to: u64,
) -> (f64, Vec<f32>) {
    let mut prof = StageProfile::new();
    let mut last = Vec::new();
    let t0 = Instant::now();
    for t in from..to {
        let x = token(t);
        let ys = model
            .forward_batch_with(std::slice::from_ref(&x), ctx, &mut prof)
            .expect("decode step");
        last = ys.into_iter().next().expect("one output").data;
    }
    (t0.elapsed().as_secs_f64(), last)
}

fn main() {
    let quick = std::env::var("DEEPGEMM_BENCH_QUICK").ok().as_deref() == Some("1");
    // P + G must fit the compiled decode window (max_seq positions).
    let max_seq = zoo::TINY_TRANSFORMER_DIMS.5 as u64;
    let (prefill, decode) = if quick { (8u64, 16u64) } else { (16u64, 48u64) };
    assert!(prefill + decode <= max_seq);
    tile::set_default_threads(1);
    let graph = zoo::build("tiny_transformer", VOCAB, 11).expect("build");
    let calib: Vec<Tensor> = (0..2).map(token).collect();
    let backends: Vec<Backend> = if quick {
        vec![Backend::Fp32, Backend::Int8, Backend::Lut16(Scheme::D)]
    } else {
        vec![
            Backend::Fp32,
            Backend::Int8,
            Backend::Lut16(Scheme::D),
            Backend::Lut65k,
            Backend::LutWide(4),
            Backend::Lut16F32,
        ]
    };
    let isas: Vec<Isa> = Isa::ALL.into_iter().filter(|i| i.is_supported()).collect();
    let mut table = Table::new(
        format!("Decode throughput — tiny_transformer, prefill {prefill} + decode {decode}"),
        &["prefill tok/s", "decode tok/s", "us/token"],
    );
    for &backend in &backends {
        let model = CompiledModel::compile(graph.clone(), backend, &calib).expect("compile");
        for &isa in &isas {
            simd::set_requested(Some(isa));
            let mut ctx = model.new_ctx();
            // One throwaway step warms arena/scratch/KV capacities, then
            // the context rewinds so the timed prefill starts at pos 0.
            let _ = run_span(&model, &mut ctx, 0, 1);
            ctx.reset_decode();
            let gemv_before = tile::gemv_executes();
            let (t_prefill, _) = run_span(&model, &mut ctx, 0, prefill);
            let (t_decode, last) = run_span(&model, &mut ctx, prefill, prefill + decode);
            assert!(
                last.iter().all(|v| v.is_finite()),
                "{}/{}: non-finite logits",
                backend.name(),
                isa.name()
            );
            if backend != Backend::Fp32 {
                assert!(
                    tile::gemv_executes() > gemv_before,
                    "{}/{}: decode never took the GEMV row path",
                    backend.name(),
                    isa.name()
                );
            }
            let tps_p = prefill as f64 / t_prefill;
            let tps_d = decode as f64 / t_decode;
            eprintln!(
                "[decode] {}@{}: prefill {tps_p:.0} tok/s, decode {tps_d:.0} tok/s",
                backend.name(),
                isa.name()
            );
            table.row(
                format!("{}@{}", backend.name(), isa.name()),
                vec![tps_p, tps_d, t_decode / decode as f64 * 1e6],
            );
        }
    }
    simd::set_requested(None);
    // End-to-end oracle: GEMV-routed decode must be bit-identical to
    // the same model forced through the tiled grid driver.
    let mut model =
        CompiledModel::compile(graph, Backend::Lut16(Scheme::D), &calib).expect("compile");
    let mut ctx = model.new_ctx();
    let (_, fast) = run_span(&model, &mut ctx, 0, 6);
    model.set_gemv(false);
    let mut ctx = model.new_ctx();
    let (_, tiled) = run_span(&model, &mut ctx, 0, 6);
    assert_eq!(fast, tiled, "GEMV decode diverged from the forced-tiled oracle");
    table.note("single worker thread; every step is a per-token forward (M = 1 GEMMs)");
    table.note("GEMV row-path selection asserted via kernels::tile counters");
    table.note("lut16-d logits verified bit-identical against the forced-tiled driver");
    table.note(format!(
        "model dims (d, heads, head_dim, ffn, layers, max_seq) = {:?}",
        zoo::TINY_TRANSFORMER_DIMS
    ));
    print!("{}", table.render());
    table.write_json("decode_tokens_per_sec").expect("write json");
}
