//! Paper Tab. 3 + Fig. 4: packing schemes a–d — symbolic instruction
//! counts (ours vs the paper's) and *measured* GEMM + activation-packing
//! latency per scheme.
//!
//! Expected shape: total visible ops a > b ≥ c > d, and scheme d fastest
//! in measured cycles (the paper's conclusion). Our reconstructions of
//! b–d differ in detail from the paper's (see kernels::pack docs); both
//! count sets are printed side by side.

use deepgemm::bench::{bench, support, threads_axis, BenchOpts, Table};
use deepgemm::kernels::pack::{self, Scheme};
use deepgemm::kernels::{tile, Backend, CodeMat, GemmSize};
use deepgemm::profiling::icount::{paper_tab3, scheme_icount};

fn main() {
    let opts = BenchOpts::from_env();
    // Scheme comparison at one worker (the paper's single-core setting)
    // unless --threads overrides it; all schemes run tiled plans. This
    // bench has no thread axis — a multi-value list collapses to its
    // maximum, loudly.
    let taxis = threads_axis(&[1]);
    let nt = *taxis.last().unwrap();
    if taxis.len() > 1 {
        eprintln!("[tab3] no thread axis here; measuring at the max, --threads {nt}");
    }
    tile::set_default_threads(nt);
    let size = GemmSize::new(128, 64, 1152);
    let mut t = Table::new(
        "Tab 3 — packing schemes: instructions per output (ours | paper) + measured",
        &[
            "AND", "shift", "OR", "shuffle", "total",
            "paper total", "gemm ms", "act-pack ms",
        ],
    );
    for scheme in Scheme::ALL {
        let ic = scheme_icount(scheme);
        let pc = paper_tab3(scheme);
        let secs = support::time_backend(Backend::Lut16(scheme), size, &opts);
        // Activation packing cost for this scheme's layout.
        let a = CodeMat::random(size.m, size.k, 2, 7);
        let pack_secs = bench(format!("pack-{}", scheme.name()), &opts, || {
            std::hint::black_box(pack::pack_activations(&a, scheme));
        })
        .secs();
        t.row(
            format!("scheme {}", scheme.name()),
            vec![
                ic.and,
                ic.shift,
                ic.or,
                ic.shuffle,
                ic.total(),
                pc.total(),
                secs * 1e3,
                pack_secs * 1e3,
            ],
        );
    }
    t.note(format!(
        "gemm at (M,N,K)=({},{},{}); paper totals 5.5/4.5/4.5/4.0 — same ordering, d wins",
        size.m, size.n, size.k
    ));
    t.note("scheme c trades 4x weight bytes for zero unpack shifts; d nibble-packs both operands (2x bytes)");
    t.note(format!("tiled plans at {nt} worker thread(s) (paper setting: 1)"));
    print!("{}", t.render());
    // Bare artifact name stays reserved for the single-thread
    // paper-setting numbers (same convention as fig7).
    let file = if nt == 1 {
        "tab3_packing_schemes".to_string()
    } else {
        format!("tab3_packing_schemes_t{nt}")
    };
    t.write_json(&file).expect("write json");

    // Sanity: measured ordering must put d at or near the front.
    let times: Vec<f64> = t.rows.iter().map(|(_, v)| v[6]).collect();
    let d = times[3];
    assert!(
        d <= times[0] * 1.05,
        "scheme d ({d:.3} ms) should not lose to scheme a ({:.3} ms)",
        times[0]
    );
}
