//! Paper Fig. 7: low-level kernel profiling on x86 — per-stage breakdown
//! of the quantized convolution pipeline (act-quantize / act-pack /
//! Lut-Conv / dequantize; like the paper, im2col is folded into packing —
//! the fused implicit-GEMM path gathers im2col rows inside the pack
//! stage, so no standalone im2col row appears for these backends, and
//! the tiled backends' dequant epilogue runs inside Lut-Conv), plus the
//! intra-LutConv unpack/lookup/accumulate split that the paper
//! attributes ~80% / ~20% via VTune.
//!
//! Expected shape: Lut-Conv dominates; within it, unpacking is the
//! majority (the paper's headline profiling insight and the motivation
//! for schemes b–d and future work).

use deepgemm::bench::{bench, threads_axis, BenchOpts, Table};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::{self, Scheme};
use deepgemm::kernels::{tile, Backend, CodeMat};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::{Stage, StageProfile};
use deepgemm::quant::{IntCodebook, Lut16};

fn stage_table(model: &CompiledModel, x: &Tensor, iters: usize) -> Table {
    let mut prof = StageProfile::new();
    // Reuse one ExecCtx across iterations, exactly like a serving worker:
    // the warmup grows arena + scratch, the timed runs are allocation-free.
    let mut ctx = model.new_ctx();
    let xs = std::slice::from_ref(x);
    model.forward_batch_with(xs, &mut ctx, &mut StageProfile::new()).expect("warmup");
    for _ in 0..iters {
        model.forward_batch_with(xs, &mut ctx, &mut prof).expect("fwd");
    }
    let mut t = Table::new(
        format!("Fig 7 — stage breakdown: {} / {}", model.name, model.backend.name()),
        &["ms", "% of total"],
    );
    let total = prof.total();
    for st in Stage::ALL {
        if prof.calls(st) > 0 {
            t.row(st.name(), vec![prof.secs(st) * 1e3 / iters as f64, 100.0 * prof.secs(st) / total]);
        }
    }
    t
}

/// Intra-LutConv split via materialized two-pass execution (scheme a):
/// pass 1 computes the 4 index vectors per 32-byte chunk (unpack); pass 2
/// does shuffle+sad from the materialized indices (lookup+accumulate).
#[cfg(target_arch = "x86_64")]
mod split {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_pass(a: &[u8], w: &[u8], idx_out: &mut [u8]) {
        let m3 = _mm256_set1_epi8(0x03);
        let mc = _mm256_set1_epi8(0x0C);
        let chunks = a.len() / 32;
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(32 * c) as *const __m256i);
            let vw = _mm256_loadu_si256(w.as_ptr().add(32 * c) as *const __m256i);
            let i0 = _mm256_or_si256(
                _mm256_and_si256(_mm256_slli_epi32(vw, 2), mc),
                _mm256_and_si256(va, m3),
            );
            let i1 = _mm256_or_si256(
                _mm256_and_si256(vw, mc),
                _mm256_and_si256(_mm256_srli_epi32(va, 2), m3),
            );
            let i2 = _mm256_or_si256(
                _mm256_and_si256(_mm256_srli_epi32(vw, 2), mc),
                _mm256_and_si256(_mm256_srli_epi32(va, 4), m3),
            );
            let i3 = _mm256_or_si256(
                _mm256_and_si256(_mm256_srli_epi32(vw, 4), mc),
                _mm256_and_si256(_mm256_srli_epi32(va, 6), m3),
            );
            for (r, v) in [i0, i1, i2, i3].into_iter().enumerate() {
                _mm256_storeu_si256(
                    idx_out.as_mut_ptr().add(128 * c + 32 * r) as *mut __m256i,
                    v,
                );
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn lookup_accum_pass(idx: &[u8], table: &[u8; 16]) -> i64 {
        let tt = _mm_loadu_si128(table.as_ptr() as *const __m128i);
        let lut = _mm256_broadcastsi128_si256(tt);
        let zero = _mm256_setzero_si256();
        let mut acc = _mm256_setzero_si256();
        for c in 0..idx.len() / 32 {
            let iv = _mm256_loadu_si256(idx.as_ptr().add(32 * c) as *const __m256i);
            let prod = _mm256_shuffle_epi8(lut, iv);
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(prod, zero));
        }
        let lo = _mm256_castsi256_si128(acc);
        let hi = _mm256_extracti128_si256(acc, 1);
        let d = _mm_add_epi64(hi, lo);
        let e = _mm_shuffle_epi32(d, 238);
        _mm_cvtsi128_si64(_mm_add_epi64(e, d))
    }
}

fn main() {
    let quick = std::env::var("DEEPGEMM_BENCH_QUICK").ok().as_deref() == Some("1");
    // Stage breakdown on a real network, one table per (backend,
    // --threads entry): every tiled backend — lut16-d, the int8
    // baseline and the 4-bit wide LUT — fans out on the same axis, so
    // the Lut-Conv share shrinks comparably across engines.
    let model_name = if quick { "small_cnn" } else { "resnet18" };
    let graph = zoo::build(model_name, 1000, 0).expect("build");
    let (c, h, w) = graph.input_chw;
    let x = Tensor::random(&[1, c, h, w], 3, -1.0, 1.0);
    let backends = [
        ("lut16-d", Backend::Lut16(Scheme::D)),
        ("int8", Backend::Int8),
        ("lut4b", Backend::LutWide(4)),
    ];
    for (bname, backend) in backends {
        // Compile once per backend — only the forward passes depend on
        // the thread count.
        let model = CompiledModel::compile(graph.clone(), backend, &[x.clone()])
            .expect("compile");
        for &nt in &threads_axis(&[1]) {
            tile::set_default_threads(nt);
            let mut t = stage_table(&model, &x, if quick { 1 } else { 2 });
            t.title = format!(
                "{} [threads={nt} isa={}]",
                t.title,
                deepgemm::kernels::simd::active().name()
            );
            print!("{}", t.render());
            // The bare artifact names stay reserved for the lut16-d
            // paper-comparison numbers; other backends get their own
            // files.
            let file = match (bname, nt) {
                ("lut16-d", 1) => "fig7_stages".to_string(),
                ("lut16-d", _) => format!("fig7_stages_t{nt}"),
                _ => format!("fig7_stages_{bname}_t{nt}"),
            };
            t.write_json(&file).expect("json");
        }
    }

    // Intra-LutConv split (paper: unpack ≈ 80% of Lut-Conv).
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            let opts = BenchOpts::from_env();
            let k = 1 << 16; // 64k values per row
            let a = CodeMat::random(1, k, 2, 1);
            let w = CodeMat::random(1, k, 2, 2);
            let ap = pack::pack(&a, pack::Layout::Dense);
            let wp = pack::pack(&w, pack::Layout::Dense);
            let lut = Lut16::build(&IntCodebook::signed(2), &IntCodebook::unsigned(2));
            let mut table = [0u8; 16];
            table.copy_from_slice(&lut.table);
            let mut idx = vec![0u8; ap.row(0).len() * 4];
            let t_unpack = bench("unpack", &opts, || unsafe {
                split::unpack_pass(ap.row(0), wp.row(0), &mut idx);
                std::hint::black_box(&idx);
            })
            .secs();
            let t_lookup = bench("lookup+accum", &opts, || unsafe {
                std::hint::black_box(split::lookup_accum_pass(&idx, &table));
            })
            .secs();
            let mut t2 = Table::new(
                "Fig 7 (inset) — inside Lut-Conv (scheme a, materialized passes)",
                &["ms per 64k MACs", "% of Lut-Conv"],
            );
            let total = t_unpack + t_lookup;
            t2.row("unpack", vec![t_unpack * 1e3, 100.0 * t_unpack / total]);
            t2.row("lookup+accumulate", vec![t_lookup * 1e3, 100.0 * t_lookup / total]);
            t2.note("paper (VTune): unpack ~80% of Lut-Conv");
            print!("{}", t2.render());
            t2.write_json("fig7_lutconv_split").expect("json");
        }
    }
}
