//! `cargo xtask` — repo automation for the deepgemm workspace.
//!
//! The only subcommand is `audit`, the unsafe-code static auditor (see
//! `docs/SAFETY.md`). It lexes `src/` (comments, strings, char literals
//! and raw strings are masked before any rule runs) and enforces:
//!
//! - every `unsafe {}` block / `unsafe impl` carries a `// SAFETY:`
//!   comment immediately above it; every `unsafe fn` carries one above
//!   its declaration or inside its body;
//! - every `#[target_feature]` function either asserts a registered
//!   kernel contract at entry (`contract_assert!`, declared via
//!   `kernel_contract!`) or is marked `// CONTRACT: helper`;
//! - no hand-written `debug_assert*` remains inside a
//!   `#[target_feature]` function (preconditions belong to contracts);
//! - forbidden patterns (`static mut`, `transmute`, `get_unchecked`,
//!   `from_raw_parts`) appear only at allow-listed (file, token) pairs;
//! - the full unsafe inventory (file, line, kind, justification hash)
//!   matches the checked-in `unsafe_inventory.json` baseline — compared
//!   line-agnostically, so pure code motion never trips it, but any new
//!   or removed unsafe site requires `--write-baseline` in the same PR.
//!
//! `--table` additionally regenerates the backend × ISA contract table
//! in `docs/SIMD.md` from the `kernel_contract!` declarations.
//!
//! The auditor is zero-dependency on purpose: the build image is fully
//! offline, so the lexer, JSON reader/writer and diffing are hand-rolled
//! (mirroring the main crate's no-deps policy). Scope is `rust/src`
//! only — tests, benches and this tool itself are not audited.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

// ---------------------------------------------------------------------------
// Lexer: mask comments / strings / chars, record line comments.
// ---------------------------------------------------------------------------

/// A source file with comments and literals blanked out (same byte
/// length as the input, newlines preserved) plus the extracted line
/// comments.
struct Masked {
    /// The masked code: every comment/string/char byte replaced by a
    /// space (newlines kept), so token scans cannot be confused.
    code: Vec<u8>,
    /// Line-comment text per line (1-based), leading `/`/`!` stripped
    /// and trimmed. Only `//`-style comments are recorded; block
    /// comments are masked but carry no SAFETY semantics here.
    comments: BTreeMap<usize, String>,
    /// Byte offset of the start of each line (0-based index = line - 1).
    line_starts: Vec<usize>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn line_of(line_starts: &[usize], off: usize) -> usize {
    match line_starts.binary_search(&off) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

fn mask(src: &str) -> Masked {
    let b = src.as_bytes();
    let mut code = b.to_vec();
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut line_starts = vec![0usize];
    for (i, &c) in b.iter().enumerate() {
        if c == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let blank = |code: &mut [u8], from: usize, to: usize| {
        let to = to.min(code.len());
        for ch in &mut code[from..to] {
            if *ch != b'\n' {
                *ch = b' ';
            }
        }
    };
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        // Line comment.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            let text = src[start + 2..i].trim_start_matches(['/', '!']).trim().to_string();
            let line = line_of(&line_starts, start);
            // Keep the first comment on a line (trailing same-line runs
            // do not occur in this codebase).
            comments.entry(line).or_insert(text);
            blank(&mut code, start, i);
            continue;
        }
        // Block comment (nesting, as in Rust).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut code, start, i);
            continue;
        }
        // String literal.
        if c == b'"' {
            let start = i;
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' {
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            blank(&mut code, start, i);
            continue;
        }
        // Identifier — or a raw-string prefix (r"", r#""#, br"").
        if c.is_ascii_alphabetic() || c == b'_' {
            let at_token_start = i == 0 || !is_ident(b[i - 1]);
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            if at_token_start && j < b.len() && b[j] == b'r' {
                let mut k = j + 1;
                while k < b.len() && b[k] == b'#' {
                    k += 1;
                }
                if k < b.len() && b[k] == b'"' {
                    let hashes = k - (j + 1);
                    let mut m = k + 1;
                    while m < b.len() {
                        if b[m] == b'"' {
                            let mut h = 0usize;
                            while h < hashes && m + 1 + h < b.len() && b[m + 1 + h] == b'#' {
                                h += 1;
                            }
                            if h == hashes {
                                m += 1 + hashes;
                                break;
                            }
                        }
                        m += 1;
                    }
                    blank(&mut code, i, m);
                    i = m;
                    continue;
                }
            }
            while i < b.len() && is_ident(b[i]) {
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if i + 1 < b.len() && b[i + 1] == b'\\' {
                let start = i;
                let mut j = i + 3; // skip quote, backslash, escaped char
                while j < b.len() && b[j] != b'\'' {
                    j += 1;
                }
                j += 1;
                blank(&mut code, start, j);
                i = j;
                continue;
            }
            if i + 2 < b.len() && b[i + 2] == b'\'' {
                blank(&mut code, i, i + 3);
                i += 3;
                continue;
            }
            i += 1; // lifetime: skip the quote only
            continue;
        }
        i += 1;
    }
    Masked { code, comments, line_starts }
}

// ---------------------------------------------------------------------------
// Token scanning helpers over masked code.
// ---------------------------------------------------------------------------

/// All identifier-like tokens of the masked code, with byte offsets.
fn tokens(code: &[u8]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if code[i].is_ascii_alphabetic() || code[i] == b'_' {
            let start = i;
            while i < code.len() && is_ident(code[i]) {
                i += 1;
            }
            out.push((start, String::from_utf8_lossy(&code[start..i]).into_owned()));
        } else {
            i += 1;
        }
    }
    out
}

fn skip_ws(code: &[u8], mut i: usize) -> usize {
    while i < code.len() && (code[i] as char).is_whitespace() {
        i += 1;
    }
    i
}

/// Offset just past the matching `}` for the `{` at `open` (which must
/// point at a `{` in masked code).
fn match_brace(code: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < code.len() {
        match code[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    code.len()
}

/// The masked text of line `line` (1-based).
fn line_slice(m: &Masked, line: usize) -> &str {
    let start = m.line_starts[line - 1];
    let end = m.line_starts.get(line).copied().unwrap_or(m.code.len());
    std::str::from_utf8(&m.code[start..end]).unwrap_or("").trim_end_matches('\n')
}

/// The contiguous comment run immediately above `line`, oldest first.
/// Attribute-only lines between the run and `line` are skipped.
fn comment_run_above(m: &Masked, line: usize) -> Vec<String> {
    let mut texts: Vec<String> = Vec::new();
    let mut l = line;
    while l > 1 {
        l -= 1;
        let code_text = line_slice(m, l).trim().to_string();
        if let Some(t) = m.comments.get(&l) {
            if code_text.is_empty() {
                texts.push(t.clone());
                continue;
            }
            break; // trailing comment on a code line — not a run
        }
        if texts.is_empty() && code_text.starts_with("#[") {
            continue; // attributes between the decl and its comments
        }
        break;
    }
    texts.reverse();
    texts
}

/// Join a comment run into a justification string starting at the first
/// line that contains `SAFETY:`; `None` when the run has no SAFETY line.
fn safety_text(run: &[String]) -> Option<String> {
    let start = run.iter().position(|t| t.contains("SAFETY:"))?;
    Some(run[start..].join(" "))
}

/// First SAFETY comment run whose line falls inside [from_line, to_line].
fn safety_in_span(m: &Masked, from_line: usize, to_line: usize) -> Option<String> {
    for (&l, t) in m.comments.range(from_line..=to_line) {
        if t.contains("SAFETY:") {
            let mut parts = vec![t.clone()];
            let mut nl = l + 1;
            while nl <= to_line {
                match m.comments.get(&nl) {
                    Some(next) if line_slice(m, nl).trim().is_empty() => {
                        parts.push(next.clone());
                        nl += 1;
                    }
                    _ => break,
                }
            }
            return Some(parts.join(" "));
        }
    }
    None
}

/// FNV-1a 64-bit over UTF-8 bytes, rendered as `fnv1a:<16 hex digits>`.
fn fnv1a(s: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("fnv1a:{h:016x}")
}

// ---------------------------------------------------------------------------
// Audit proper.
// ---------------------------------------------------------------------------

/// One rule failure, printed as `file:line: [rule] message`.
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

/// One unsafe site in the inventory.
#[derive(Clone)]
struct Entry {
    file: String,
    line: usize,
    kind: &'static str,
    hash: String,
}

/// A `kernel_contract!` declaration (fields used by `--table`).
struct ContractDecl {
    name: String,
    kernel: String,
    isa: String,
    features: String,
    rules: Vec<(String, String)>,
}

/// Everything the auditor learned about one file.
struct Audit {
    violations: Vec<Violation>,
    inventory: Vec<Entry>,
    contract_decls: Vec<ContractDecl>,
    /// `contract_assert!` targets: (contract name, line).
    contract_uses: Vec<(String, usize)>,
}

/// (file suffix, token) pairs exempt from the forbidden-pattern rule.
/// Each entry documents a reviewed, SAFETY-commented use.
const FORBIDDEN_ALLOW: &[(&str, &str)] = &[
    // Scoped-job lifetime erasure; the join guard bounds every borrow.
    ("src/util/pool.rs", "transmute"),
];

const FORBIDDEN: &[&str] = &["transmute", "get_unchecked", "from_raw_parts"];

fn audit_file(label: &str, src: &str) -> Audit {
    let m = mask(src);
    let toks = tokens(&m.code);
    let mut violations = Vec::new();
    let mut inventory = Vec::new();
    let mut contract_decls = Vec::new();
    let mut contract_uses = Vec::new();

    for (ti, (off, tok)) in toks.iter().enumerate() {
        let line = line_of(&m.line_starts, *off);
        match tok.as_str() {
            "unsafe" => {
                let after = skip_ws(&m.code, off + tok.len());
                let next_char = m.code.get(after).copied().unwrap_or(b' ');
                let next_tok = toks.get(ti + 1).map(|(_, t)| t.as_str()).unwrap_or("");
                if next_char == b'{' || (next_tok != "fn" && next_tok != "impl") {
                    // unsafe block (or unknown form — held to block rules)
                    let just = safety_text(&comment_run_above(&m, line));
                    if just.is_none() {
                        violations.push(Violation {
                            file: label.to_string(),
                            line,
                            rule: "missing-safety-comment",
                            msg: "unsafe block without a `// SAFETY:` comment above it".into(),
                        });
                    }
                    inventory.push(Entry {
                        file: label.to_string(),
                        line,
                        kind: "unsafe_block",
                        hash: fnv1a(&just.unwrap_or_default()),
                    });
                } else if next_tok == "impl" {
                    let just = safety_text(&comment_run_above(&m, line));
                    if just.is_none() {
                        violations.push(Violation {
                            file: label.to_string(),
                            line,
                            rule: "missing-safety-comment",
                            msg: "unsafe impl without a `// SAFETY:` comment above it".into(),
                        });
                    }
                    inventory.push(Entry {
                        file: label.to_string(),
                        line,
                        kind: "unsafe_impl",
                        hash: fnv1a(&just.unwrap_or_default()),
                    });
                } else {
                    // unsafe fn: SAFETY above the declaration or inside
                    // the body both discharge the rule.
                    let body_open = m.code[*off..].iter().position(|&c| c == b'{').map(|p| p + off);
                    let just = safety_text(&comment_run_above(&m, line)).or_else(|| {
                        body_open.and_then(|open| {
                            let close = match_brace(&m.code, open);
                            safety_in_span(
                                &m,
                                line_of(&m.line_starts, open),
                                line_of(&m.line_starts, close.saturating_sub(1)),
                            )
                        })
                    });
                    if just.is_none() {
                        violations.push(Violation {
                            file: label.to_string(),
                            line,
                            rule: "missing-safety-comment",
                            msg: "unsafe fn without a `// SAFETY:` comment (above or in body)"
                                .into(),
                        });
                    }
                    inventory.push(Entry {
                        file: label.to_string(),
                        line,
                        kind: "unsafe_fn",
                        hash: fnv1a(&just.unwrap_or_default()),
                    });
                }
            }
            "target_feature" => {
                // Attribute — find the decorated fn and inspect its body.
                let fn_tok = toks[ti + 1..].iter().find(|(_, t)| t == "fn");
                let Some((fn_off, _)) = fn_tok else { continue };
                let Some(open_rel) = m.code[*fn_off..].iter().position(|&c| c == b'{') else {
                    continue;
                };
                let open = fn_off + open_rel;
                let close = match_brace(&m.code, open);
                let body = &m.code[open..close];
                let body_txt = String::from_utf8_lossy(body);
                let from_line = line_of(&m.line_starts, open);
                let to_line = line_of(&m.line_starts, close.saturating_sub(1));
                let has_contract = body_txt.contains("contract_assert!");
                let helper = m
                    .comments
                    .range(from_line..=to_line)
                    .any(|(_, t)| t.contains("CONTRACT: helper"));
                if !has_contract && !helper {
                    violations.push(Violation {
                        file: label.to_string(),
                        line,
                        rule: "missing-contract",
                        msg: "#[target_feature] fn has neither `contract_assert!` at entry \
                              nor a `// CONTRACT: helper` marker"
                            .into(),
                    });
                }
                for (boff, btok) in &toks {
                    if *boff >= open && *boff < close && btok.starts_with("debug_assert") {
                        violations.push(Violation {
                            file: label.to_string(),
                            line: line_of(&m.line_starts, *boff),
                            rule: "debug-assert-in-kernel",
                            msg: "hand-written debug_assert inside a #[target_feature] fn; \
                                  declare the precondition in its kernel_contract! instead"
                                .into(),
                        });
                    }
                }
            }
            "kernel_contract" => {
                // Declaration site: `kernel_contract! { ... }` (the
                // macro's own definition is followed by `{`, not `!`).
                let after = skip_ws(&m.code, off + tok.len());
                if m.code.get(after) != Some(&b'!') {
                    continue;
                }
                let Some(open_rel) = m.code[after..].iter().position(|&c| c == b'{') else {
                    continue;
                };
                let open = after + open_rel;
                let close = match_brace(&m.code, open);
                if let Some(decl) = parse_contract_decl(src, &m, &toks, open, close) {
                    contract_decls.push(decl);
                }
            }
            "contract_assert" => {
                let after = skip_ws(&m.code, off + tok.len());
                if m.code.get(after) != Some(&b'!') {
                    continue;
                }
                let Some(paren_rel) = m.code[after..].iter().position(|&c| c == b'(') else {
                    continue;
                };
                let from = after + paren_rel + 1;
                let to = m.code[from..]
                    .iter()
                    .position(|&c| c == b',')
                    .map(|p| p + from)
                    .unwrap_or(from);
                let path = String::from_utf8_lossy(&m.code[from..to]).trim().to_string();
                let name = path.rsplit("::").next().unwrap_or(&path).trim().to_string();
                if !name.is_empty() {
                    contract_uses.push((name, line));
                }
            }
            "static" => {
                if toks.get(ti + 1).map(|(_, t)| t.as_str()) == Some("mut") {
                    violations.push(Violation {
                        file: label.to_string(),
                        line,
                        rule: "forbidden-pattern",
                        msg: "`static mut` is forbidden; use atomics or interior mutability"
                            .into(),
                    });
                }
            }
            t if FORBIDDEN.contains(&t) => {
                let allowed = FORBIDDEN_ALLOW
                    .iter()
                    .any(|(file, word)| label.ends_with(file) && *word == t);
                if !allowed {
                    violations.push(Violation {
                        file: label.to_string(),
                        line,
                        rule: "forbidden-pattern",
                        msg: format!(
                            "`{t}` outside the allow-list; if this use is reviewed and \
                             sound, add ({label:?}, {t:?}) to FORBIDDEN_ALLOW in xtask"
                        ),
                    });
                }
            }
            _ => {}
        }
    }
    Audit { violations, inventory, contract_decls, contract_uses }
}

/// Parse one `kernel_contract! { ... }` block span (masked offsets;
/// original text sliced by the same offsets, masking preserves length).
fn parse_contract_decl(
    src: &str,
    m: &Masked,
    toks: &[(usize, String)],
    open: usize,
    close: usize,
) -> Option<ContractDecl> {
    let name = toks
        .iter()
        .find(|(o, t)| *o >= open && *o < close && t == "static")
        .and_then(|(o, _)| toks.iter().find(|(o2, _)| *o2 > *o))
        .map(|(_, t)| t.clone())?;
    let orig = &src[open..close];
    let masked_block = String::from_utf8_lossy(&m.code[open..close]).into_owned();
    let kernel = quoted_field(orig, &masked_block, "kernel:")?;
    let features = quoted_field(orig, &masked_block, "features:").unwrap_or_default();
    let isa = {
        let at = masked_block.find("isa:")?;
        orig[at + 4..]
            .split(|c: char| c == ',' || c == '\n')
            .next()
            .unwrap_or("")
            .trim()
            .to_string()
    };
    let mut rules = Vec::new();
    if let Some(rat) = masked_block.find("rules:") {
        let rules_open = masked_block[rat..].find('{').map(|p| p + rat)?;
        let rules_close = match_brace(&m.code[open..close], rules_open);
        for raw in orig[rules_open + 1..rules_close.saturating_sub(1)].lines() {
            let t = raw.trim();
            let Some(colon) = t.find(':') else { continue };
            let rname = t[..colon].trim();
            if rname.is_empty() || !rname.bytes().all(is_ident) {
                continue;
            }
            let rest = &t[colon + 1..];
            let Some(q1) = rest.find('"') else { continue };
            let Some(q2) = rest[q1 + 1..].find('"') else { continue };
            rules.push((rname.to_string(), rest[q1 + 1..q1 + 1 + q2].to_string()));
        }
    }
    Some(ContractDecl { name, kernel, isa, features, rules })
}

/// Find `key` in the masked block, then return the first quoted string
/// after it from the original text.
fn quoted_field(orig: &str, masked_block: &str, key: &str) -> Option<String> {
    let at = masked_block.find(key)?;
    let rest = &orig[at + key.len()..];
    let q1 = rest.find('"')?;
    let q2 = rest[q1 + 1..].find('"')?;
    Some(rest[q1 + 1..q1 + 1 + q2].to_string())
}

// ---------------------------------------------------------------------------
// Baseline (unsafe_inventory.json).
// ---------------------------------------------------------------------------

fn render_inventory(entries: &[Entry]) -> String {
    let mut out = String::new();
    out.push_str("{\n  \"schema\": 1,\n  \"tool\": \"cargo xtask audit\",\n  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{ \"file\": \"{}\", \"line\": {}, \"kind\": \"{}\", \"hash\": \"{}\" }}{}\n",
            e.file, e.line, e.kind, e.hash, comma
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Extract `"key": "value"` from a single JSON object line.
fn json_str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\": \"");
    let at = line.find(&pat)? + pat.len();
    let end = line[at..].find('"')? + at;
    Some(line[at..end].to_string())
}

fn parse_inventory(text: &str) -> Vec<(String, String, String)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let (Some(file), Some(kind), Some(hash)) = (
            json_str_field(line, "file"),
            json_str_field(line, "kind"),
            json_str_field(line, "hash"),
        ) else {
            continue;
        };
        out.push((file, kind, hash));
    }
    out
}

/// Line-agnostic multiset diff: (file, kind, hash) triples vs baseline.
fn diff_baseline(current: &[Entry], baseline: &[(String, String, String)]) -> Vec<Violation> {
    let mut counts: BTreeMap<(String, String, String), i64> = BTreeMap::new();
    for e in current {
        *counts.entry((e.file.clone(), e.kind.to_string(), e.hash.clone())).or_default() += 1;
    }
    for b in baseline {
        *counts.entry(b.clone()).or_default() -= 1;
    }
    let mut out = Vec::new();
    for ((file, kind, hash), n) in counts {
        if n > 0 {
            let line = current
                .iter()
                .find(|e| e.file == file && e.kind == kind && e.hash == hash)
                .map(|e| e.line)
                .unwrap_or(0);
            out.push(Violation {
                file,
                line,
                rule: "baseline",
                msg: format!(
                    "new or changed {kind} ({hash}, x{n}) not in unsafe_inventory.json; \
                     review it and run `cargo xtask audit --write-baseline`"
                ),
            });
        } else if n < 0 {
            out.push(Violation {
                file: file.clone(),
                line: 0,
                rule: "baseline",
                msg: format!(
                    "stale baseline entry {kind} ({hash}, x{}) no longer in the tree; \
                     run `cargo xtask audit --write-baseline`",
                    -n
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Contract table (docs/SIMD.md).
// ---------------------------------------------------------------------------

const TABLE_START: &str = "<!-- contract-table:start -->";
const TABLE_END: &str = "<!-- contract-table:end -->";

fn render_table(decls: &[ContractDecl]) -> String {
    let mut rows: Vec<&ContractDecl> = decls.iter().collect();
    // Test-module contracts (kernel path under `tests`) are registered
    // for the unregistered-contract check but kept out of the docs.
    rows.retain(|d| !d.kernel.contains("::tests::"));
    rows.sort_by(|a, b| a.kernel.cmp(&b.kernel));
    let mut out = String::new();
    out.push_str("<!-- generated by `cargo xtask audit --table`; do not edit by hand -->\n\n");
    out.push_str("| contract | kernel | ISA arm | CPU features | preconditions |\n");
    out.push_str("|---|---|---|---|---|\n");
    for d in rows {
        let pre = d
            .rules
            .iter()
            .map(|(_, expr)| format!("`{expr}`"))
            .collect::<Vec<_>>()
            .join("; ");
        let feats =
            if d.features.is_empty() { "—".to_string() } else { format!("`{}`", d.features) };
        out.push_str(&format!(
            "| `{}` | `{}` | {} | {} | {} |\n",
            d.name,
            d.kernel,
            d.isa.to_lowercase(),
            feats,
            pre
        ));
    }
    out
}

fn splice_table(doc: &str, table: &str) -> Option<String> {
    let start = doc.find(TABLE_START)? + TABLE_START.len();
    let end = doc.find(TABLE_END)?;
    Some(format!("{}\n{}{}", &doc[..start], table, &doc[end..]))
}

// ---------------------------------------------------------------------------
// Driver.
// ---------------------------------------------------------------------------

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("audit") => {}
        _ => {
            eprintln!("usage: cargo xtask audit [--write-baseline] [--table]");
            return ExitCode::FAILURE;
        }
    }
    let write_baseline = args.iter().any(|a| a == "--write-baseline");
    let table = args.iter().any(|a| a == "--table");

    let ws_root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf();
    let src_dir = ws_root.join("src");
    let baseline_path = ws_root.join("unsafe_inventory.json");

    let mut files = Vec::new();
    if let Err(e) = walk(&src_dir, &mut files) {
        eprintln!("error: cannot walk {}: {e}", src_dir.display());
        return ExitCode::FAILURE;
    }

    let mut violations = Vec::new();
    let mut inventory = Vec::new();
    let mut decls = Vec::new();
    let mut uses = Vec::new();
    for path in &files {
        let label = path
            .strip_prefix(&ws_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: cannot read {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
        };
        let mut a = audit_file(&label, &src);
        violations.append(&mut a.violations);
        inventory.extend(a.inventory);
        decls.extend(a.contract_decls);
        uses.extend(a.contract_uses.into_iter().map(|(n, l)| (label.clone(), n, l)));
    }

    // Cross-file: every contract_assert! target must be declared.
    let declared: Vec<&str> = decls.iter().map(|d| d.name.as_str()).collect();
    for (file, name, line) in &uses {
        if !declared.contains(&name.as_str()) {
            violations.push(Violation {
                file: file.clone(),
                line: *line,
                rule: "unregistered-contract",
                msg: format!("contract_assert! names `{name}` but no kernel_contract! declares it"),
            });
        }
    }

    inventory.sort_by(|a, b| (&a.file, a.line, a.kind).cmp(&(&b.file, b.line, b.kind)));

    if write_baseline {
        if let Err(e) = std::fs::write(&baseline_path, render_inventory(&inventory)) {
            eprintln!("error: cannot write {}: {e}", baseline_path.display());
            return ExitCode::FAILURE;
        }
        println!(
            "wrote {} ({} unsafe sites)",
            baseline_path.display(),
            inventory.len()
        );
    } else {
        match std::fs::read_to_string(&baseline_path) {
            Ok(text) => violations.extend(diff_baseline(&inventory, &parse_inventory(&text))),
            Err(_) => violations.push(Violation {
                file: "unsafe_inventory.json".into(),
                line: 0,
                rule: "baseline",
                msg: "baseline missing; run `cargo xtask audit --write-baseline`".into(),
            }),
        }
    }

    if table {
        let simd_md = ws_root.parent().map(|r| r.join("docs").join("SIMD.md"));
        let rendered = render_table(&decls);
        print!("{rendered}");
        if let Some(simd_md) = simd_md {
            match std::fs::read_to_string(&simd_md) {
                Ok(doc) => match splice_table(&doc, &rendered) {
                    Some(updated) => {
                        if updated != doc {
                            if let Err(e) = std::fs::write(&simd_md, updated) {
                                eprintln!("error: cannot write {}: {e}", simd_md.display());
                                return ExitCode::FAILURE;
                            }
                            println!("updated {}", simd_md.display());
                        } else {
                            println!("{} already up to date", simd_md.display());
                        }
                    }
                    None => {
                        eprintln!(
                            "error: contract-table markers not found in {}",
                            simd_md.display()
                        );
                        return ExitCode::FAILURE;
                    }
                },
                Err(e) => {
                    eprintln!("error: cannot read {}: {e}", simd_md.display());
                    return ExitCode::FAILURE;
                }
            }
        }
    }

    violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    if violations.is_empty() {
        println!(
            "audit OK: {} files, {} unsafe sites, {} contracts, {} contract uses",
            files.len(),
            inventory.len(),
            decls.len(),
            uses.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("error: {}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
        }
        eprintln!("audit FAILED: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// Tests — in-memory fixtures only, so checked-in sources never trip the
// tree audit with seeded violations.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(a: &Audit) -> Vec<&'static str> {
        a.violations.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn masking_strings_comments_chars_lifetimes() {
        let src = r##"
// unsafe in a comment
let s = "unsafe { }";
let r = r#"unsafe"#;
let c = 'u';
let esc = '\'';
fn f<'a>(x: &'a str) {}
"##;
        let m = mask(src);
        let toks = tokens(&m.code);
        assert!(!toks.iter().any(|(_, t)| t == "unsafe"), "masked text leaked: {toks:?}");
        assert!(toks.iter().any(|(_, t)| t == "fn"));
        assert_eq!(m.comments.get(&2).map(String::as_str), Some("unsafe in a comment"));
    }

    #[test]
    fn unsafe_block_without_safety_is_flagged() {
        // The seeded-violation fixture: this is what CI proves the
        // auditor rejects.
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let a = audit_file("src/x.rs", src);
        assert_eq!(rules_of(&a), vec!["missing-safety-comment"]);
        assert_eq!(a.inventory.len(), 1);
        assert_eq!(a.inventory[0].kind, "unsafe_block");
    }

    #[test]
    fn unsafe_block_with_safety_passes() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        let a = audit_file("src/x.rs", src);
        assert!(a.violations.is_empty(), "{:?}", rules_of(&a));
        assert_eq!(a.inventory[0].kind, "unsafe_block");
        assert_ne!(a.inventory[0].hash, fnv1a(""));
    }

    #[test]
    fn unsafe_impl_needs_its_own_comment() {
        let src = "struct S(*mut u8);\n// SAFETY: disjoint writes only.\nunsafe impl Send for S {}\nunsafe impl Sync for S {}\n";
        let a = audit_file("src/x.rs", src);
        // Send documented, Sync (no run directly above it) flagged.
        assert_eq!(rules_of(&a), vec!["missing-safety-comment"]);
        assert_eq!(a.inventory.len(), 2);
        assert!(a.inventory.iter().all(|e| e.kind == "unsafe_impl"));
    }

    #[test]
    fn unsafe_fn_with_body_safety_passes() {
        let src = "unsafe fn k() {\n    // SAFETY: register-only.\n    unsafe { core::hint::spin_loop() }\n}\n";
        let a = audit_file("src/x.rs", src);
        assert!(a.violations.is_empty(), "{:?}", rules_of(&a));
        let kinds: Vec<_> = a.inventory.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec!["unsafe_fn", "unsafe_block"]);
    }

    #[test]
    fn target_feature_without_contract_is_flagged() {
        let src = "#[target_feature(enable = \"avx2\")]\nunsafe fn k(p: *const u8) -> u8 {\n    // SAFETY: fine.\n    unsafe { *p }\n}\n";
        let a = audit_file("src/x.rs", src);
        assert_eq!(rules_of(&a), vec!["missing-contract"]);
    }

    #[test]
    fn target_feature_with_contract_assert_passes() {
        let src = "#[target_feature(enable = \"avx2\")]\nunsafe fn k(n: usize) {\n    crate::contract_assert!(super::C_K, vals: n,);\n    // SAFETY: contract checked above.\n    unsafe { core::hint::spin_loop() }\n}\n";
        let a = audit_file("src/x.rs", src);
        assert!(a.violations.is_empty(), "{:?}", rules_of(&a));
        assert_eq!(a.contract_uses, vec![("C_K".to_string(), 3)]);
    }

    #[test]
    fn target_feature_helper_marker_passes() {
        let src = "#[target_feature(enable = \"avx2\")]\nunsafe fn h() {\n    // CONTRACT: helper — register-only.\n    // SAFETY: no memory access.\n    unsafe { core::hint::spin_loop() }\n}\n";
        let a = audit_file("src/x.rs", src);
        assert!(a.violations.is_empty(), "{:?}", rules_of(&a));
    }

    #[test]
    fn debug_assert_inside_kernel_is_flagged() {
        let src = "#[target_feature(enable = \"avx2\")]\nunsafe fn k(n: usize) {\n    crate::contract_assert!(C_K, vals: n,);\n    debug_assert_eq!(n % 2, 0);\n    // SAFETY: ok.\n    unsafe { core::hint::spin_loop() }\n}\n";
        let a = audit_file("src/x.rs", src);
        assert_eq!(rules_of(&a), vec!["debug-assert-in-kernel"]);
    }

    #[test]
    fn forbidden_patterns_and_allowlist() {
        let src = "fn f() {\n    let x: u32 = unsafe { std::mem::transmute(1i32) };\n}\n";
        let a = audit_file("src/other.rs", src);
        assert!(rules_of(&a).contains(&"forbidden-pattern"));
        // Same token in the allow-listed file passes that rule.
        let b = audit_file("src/util/pool.rs", src);
        assert!(!rules_of(&b).contains(&"forbidden-pattern"));
        let c = audit_file("src/x.rs", "static mut G: u32 = 0;\n");
        assert_eq!(rules_of(&c), vec!["forbidden-pattern"]);
    }

    #[test]
    fn contract_decl_parsing_for_table() {
        let src = r#"
crate::kernel_contract! {
    pub(crate) static C_DEMO = {
        kernel: "demo::avx2::k",
        isa: Avx2,
        features: "avx2,fma",
        doc: "Demo kernel.",
        example: { mt: 1, nt: 1, vals: 32, a_len: 32, w_len: 32, lut_len: 0 },
        rules: {
            k_chunk: "q.vals % 32 == 0" => |q| q.vals % 32 == 0,
            a_row: "q.a_len >= q.vals" => |q| q.a_len >= q.vals,
        },
    }
}
"#;
        let a = audit_file("src/x.rs", src);
        assert_eq!(a.contract_decls.len(), 1);
        let d = &a.contract_decls[0];
        assert_eq!(d.name, "C_DEMO");
        assert_eq!(d.kernel, "demo::avx2::k");
        assert_eq!(d.isa, "Avx2");
        assert_eq!(d.features, "avx2,fma");
        assert_eq!(
            d.rules,
            vec![
                ("k_chunk".to_string(), "q.vals % 32 == 0".to_string()),
                ("a_row".to_string(), "q.a_len >= q.vals".to_string()),
            ]
        );
        let table = render_table(&a.contract_decls);
        assert!(table.contains("| `C_DEMO` | `demo::avx2::k` | avx2 | `avx2,fma` |"));
        assert!(table.contains("`q.vals % 32 == 0`; `q.a_len >= q.vals`"));
    }

    #[test]
    fn baseline_roundtrip_and_diff() {
        let entries = vec![
            Entry { file: "src/a.rs".into(), line: 3, kind: "unsafe_block", hash: fnv1a("x") },
            Entry { file: "src/b.rs".into(), line: 9, kind: "unsafe_fn", hash: fnv1a("y") },
        ];
        let text = render_inventory(&entries);
        let parsed = parse_inventory(&text);
        assert_eq!(parsed.len(), 2);
        assert!(diff_baseline(&entries, &parsed).is_empty());
        // Line moves are invisible; new sites are not.
        let mut moved = entries.clone();
        moved[0].line = 33;
        assert!(diff_baseline(&moved, &parsed).is_empty());
        let mut grown = entries.clone();
        grown.push(Entry {
            file: "src/c.rs".into(),
            line: 1,
            kind: "unsafe_block",
            hash: fnv1a("z"),
        });
        let d = diff_baseline(&grown, &parsed);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "baseline");
    }

    #[test]
    fn table_splice_replaces_between_markers() {
        let doc = format!("before\n{TABLE_START}\nold\n{TABLE_END}\nafter\n");
        let out = splice_table(&doc, "NEW\n").unwrap();
        assert!(out.contains("NEW"));
        assert!(!out.contains("old"));
        assert!(out.starts_with("before\n"));
        assert!(out.ends_with("after\n"));
    }

    #[test]
    fn fnv_hash_is_stable() {
        // FNV-1a 64 test vectors (empty string and "a").
        assert_eq!(fnv1a(""), "fnv1a:cbf29ce484222325");
        assert_eq!(fnv1a("a"), "fnv1a:af63dc4c8601ec8c");
    }
}
