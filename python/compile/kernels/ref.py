"""Pure-jnp reference oracle for the DeepGEMM LUT kernels (L1).

Everything here is straight-line jax.numpy with no Pallas: the pytest
suite asserts the Pallas kernels in lut_gemm.py / pack.py reproduce these
functions bit-exactly (integer paths) or to float tolerance (f32 LUT).

Conventions (mirroring the rust side, rust/src/kernels/mod.rs):
  - a_codes: (M, K) int32 activation codes in [0, 2^bits)
  - w_codes: (N, K) int32 weight codes (weights stored transposed)
  - lut[(cw << bits) | ca] = Vw(cw) * Va(ca)
  - out[m, n] = sum_k lut[(w[n,k] << bits) | a[m,k]]
"""

import jax.numpy as jnp

#: Number of 2-bit codes packed per int32 word.
CODES_PER_WORD = {2: 16, 3: 8, 4: 8}
#: Bit stride used when packing (3-bit codes are stored in 4-bit slots so
#: shifts stay power-of-two, matching the rust Dense3 nibble layout).
SLOT_BITS = {2: 2, 3: 4, 4: 4}


def make_lut(w_values, a_values, bits):
    """Product LUT: lut[(cw << bits) | ca] = w_values[cw] * a_values[ca]."""
    w_values = jnp.asarray(w_values)
    a_values = jnp.asarray(a_values)
    assert w_values.shape == (1 << bits,)
    assert a_values.shape == (1 << bits,)
    return (w_values[:, None] * a_values[None, :]).reshape(-1)


def uniform_values(bits, signed):
    """Integer codebook values: code -> code - zp (signed) or code."""
    codes = jnp.arange(1 << bits, dtype=jnp.int32)
    return codes - (1 << (bits - 1)) if signed else codes


def pack_codes(codes, bits):
    """Pack (R, K) int32 codes into (R, K/cpw) int32 words (little-endian
    slots). K must be a multiple of CODES_PER_WORD[bits]."""
    cpw = CODES_PER_WORD[bits]
    slot = SLOT_BITS[bits]
    r, k = codes.shape
    assert k % cpw == 0, f"K={k} not a multiple of {cpw}"
    grouped = codes.reshape(r, k // cpw, cpw).astype(jnp.uint32)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * slot)[None, None, :]
    return (grouped << shifts).sum(axis=-1, dtype=jnp.uint32).astype(jnp.int32)


def unpack_codes(words, bits, k):
    """Inverse of pack_codes -> (R, K) int32 codes."""
    cpw = CODES_PER_WORD[bits]
    slot = SLOT_BITS[bits]
    mask = (1 << bits) - 1
    r, nw = words.shape
    assert nw * cpw >= k
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * slot)[None, None, :]
    u = words.astype(jnp.uint32)
    codes = (u[:, :, None] >> shifts) & mask
    return codes.reshape(r, nw * cpw)[:, :k].astype(jnp.int32)


def lut_gemm_ref(a_codes, w_codes, lut, bits):
    """Reference LUT GEMM on unpacked codes."""
    idx = (w_codes[None, :, :] << bits) | a_codes[:, None, :]  # (M, N, K)
    prods = jnp.take(lut, idx.reshape(-1)).reshape(idx.shape)
    return prods.sum(axis=-1)


def quantize_ref(x, scale, zp, bits):
    """Uniform affine quantization to codes (paper Eq. 1).

    Rounding is floor(x + 0.5) rather than jnp.round: dequantized
    activations live on an exact grid, so round-half ties actually occur,
    and jax's round-half-even disagrees with the older XLA runtime the
    rust side embeds (round-half-away). floor(+0.5) lowers identically
    in both, keeping the AOT goldens bit-exact.
    """
    q = jnp.floor(x / scale + 0.5) + zp
    return jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.int32)


def dequantize_ref(codes, scale, zp):
    return (codes.astype(jnp.float32) - zp) * scale


def quant_gemm_ref(a, w, a_scale, a_zp, w_scale, w_zp, bits):
    """End-to-end float-in/float-out quantized GEMM reference:
    quantize both operands, integer LUT GEMM with centered codebooks,
    dequantize."""
    a_codes = quantize_ref(a, a_scale, a_zp, bits)
    w_codes = quantize_ref(w, w_scale, w_zp, bits)
    wv = jnp.arange(1 << bits, dtype=jnp.int32) - w_zp
    av = jnp.arange(1 << bits, dtype=jnp.int32) - a_zp
    lut = make_lut(wv, av, bits)
    acc = lut_gemm_ref(a_codes, w_codes, lut, bits)
    return acc.astype(jnp.float32) * (a_scale * w_scale)
