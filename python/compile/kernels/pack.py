"""L1 — bit-packing and quantization Pallas kernels.

The runtime packing stage of the paper (Fig. 1a / Fig. 7 "act-pack"),
expressed for the TPU VPU: 16 2-bit codes per int32 word via shift+OR
lane ops. interpret=True per the AOT recipe.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pack_kernel(codes_ref, o_ref, *, bits):
    cpw = ref.CODES_PER_WORD[bits]
    slot = ref.SLOT_BITS[bits]
    codes = codes_ref[...].astype(jnp.uint32)
    r, k = codes.shape
    grouped = codes.reshape(r, k // cpw, cpw)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * slot)[None, None, :]
    o_ref[...] = (grouped << shifts).sum(axis=-1, dtype=jnp.uint32).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits",))
def pack_pallas(codes, bits=2):
    """Pack (R, K) int32 codes → (R, K/cpw) int32 words with a Pallas
    kernel (row-tiled)."""
    r, k = codes.shape
    cpw = ref.CODES_PER_WORD[bits]
    assert k % cpw == 0
    return pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, k // cpw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k // cpw), jnp.int32),
        interpret=True,
    )(codes)


def _quantize_kernel(x_ref, o_ref, *, scale, zp, bits):
    # floor(+0.5) for cross-runtime tie determinism — see ref.quantize_ref.
    q = jnp.floor(x_ref[...] / scale + 0.5) + zp
    o_ref[...] = jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("scale", "zp", "bits"))
def quantize_pallas(x, scale, zp, bits=2):
    """Uniform affine quantization (paper Eq. 1) as a Pallas kernel."""
    r, k = x.shape
    return pl.pallas_call(
        functools.partial(_quantize_kernel, scale=scale, zp=zp, bits=bits),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k), jnp.int32),
        interpret=True,
    )(x)


def _dequantize_kernel(acc_ref, o_ref, *, scale):
    o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("scale",))
def dequantize_pallas(acc, scale):
    """Accumulator → f32 (the Fig. 7 "dequantize" stage)."""
    r, k = acc.shape
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, scale=scale),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k), jnp.float32),
        interpret=True,
    )(acc)
