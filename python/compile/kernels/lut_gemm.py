"""L1 — the DeepGEMM LUT GEMM as a Pallas kernel (TPU-adapted, run with
interpret=True on CPU per the AOT recipe).

Hardware adaptation of the paper's AVX2 kernel (DESIGN.md §3):

  AVX2 `pshufb` 16-entry lookup  →  one-hot(index) @ LUT contraction, the
    MXU-idiomatic table lookup (a (T, 2^2b) one-hot matrix against the
    (2^2b,) LUT vector); in interpret mode XLA executes it as a gather.
  bit-unpack via `and`/`srl`      →  the same bitwise ops on int32 lanes
    (TPU VPU ops).
  BlockSpec HBM→VMEM tiling       →  (bm × K/cpw) activation tiles and
    (bn × K/cpw) weight tiles staged into VMEM; the packed 2-bit layout
    moves 16× less HBM traffic than f32.

The kernel computes  out[m, n] = Σ_k lut[(w[n,k] << bits) | a[m,k]]
over *packed* int32 operands (16 2-bit codes per word).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM tile sizes (multiples of the TPU lane count would apply on real
# hardware; interpret mode only needs them to divide the padded problem).
BM = 8
BN = 8


def _lut_lookup_onehot(lut, idx, entries):
    """Table lookup as a one-hot contraction (the MXU-friendly form)."""
    onehot = (idx[..., None] == jnp.arange(entries, dtype=idx.dtype)).astype(lut.dtype)
    return onehot @ lut


def _kernel(a_ref, w_ref, lut_ref, o_ref, *, bits, k_words, use_onehot):
    """One (BM × BN) output tile: unpack both operands' words, build
    4-bit (2·bits generally) indices, look up products, accumulate."""
    cpw = ref.CODES_PER_WORD[bits]
    slot = ref.SLOT_BITS[bits]
    mask = (1 << bits) - 1
    entries = 1 << (2 * bits)

    a_words = a_ref[...].astype(jnp.uint32)  # (BM, k_words)
    w_words = w_ref[...].astype(jnp.uint32)  # (BN, k_words)
    lut = lut_ref[...]

    # Unpack: (R, k_words, cpw) codes, flattened to (R, K).
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * slot)[None, None, :]
    a_codes = ((a_words[:, :, None] >> shifts) & mask).astype(jnp.int32)
    w_codes = ((w_words[:, :, None] >> shifts) & mask).astype(jnp.int32)
    a_codes = a_codes.reshape(a_codes.shape[0], k_words * cpw)
    w_codes = w_codes.reshape(w_codes.shape[0], k_words * cpw)

    # Index = (w << bits) | a, per (m, n, k).
    idx = (w_codes[None, :, :] << bits) | a_codes[:, None, :]
    if use_onehot:
        prods = _lut_lookup_onehot(lut, idx, entries)
    else:
        prods = jnp.take(lut, idx.reshape(-1)).reshape(idx.shape)
    o_ref[...] = prods.sum(axis=-1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "use_onehot"))
def lut_gemm_packed(a_packed, w_packed, lut, bits=2, use_onehot=False):
    """Packed LUT GEMM via pallas_call.

    a_packed: (M, KW) int32, w_packed: (N, KW) int32,
    lut: (2^(2·bits),) int32 or float32. M, N must be multiples of BM/BN
    (use `lut_gemm` for the padding wrapper).
    """
    m, kw = a_packed.shape
    n, kw2 = w_packed.shape
    assert kw == kw2, f"packed K mismatch: {kw} vs {kw2}"
    assert m % BM == 0 and n % BN == 0, f"(M={m}, N={n}) must tile by ({BM}, {BN})"
    out_dtype = jnp.float32 if lut.dtype == jnp.float32 else jnp.int32
    kernel = functools.partial(
        _kernel, bits=bits, k_words=kw, use_onehot=use_onehot
    )
    return pl.pallas_call(
        kernel,
        grid=(m // BM, n // BN),
        in_specs=[
            pl.BlockSpec((BM, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, kw), lambda i, j: (j, 0)),
            pl.BlockSpec((lut.shape[0],), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(a_packed, w_packed, lut)


def lut_gemm(a_codes, w_codes, lut, bits=2, w_zero_code=None, use_onehot=False):
    """Unpacked-codes convenience wrapper: pads M/N to tile multiples and
    K to a packing-word multiple, packs, runs the Pallas kernel, slices.

    K padding uses `w_zero_code` (the weight code whose *value* is 0) so
    padded columns contribute exactly zero — pass the weight zero-point
    for uniform signed weights (default: 2^(bits-1)).
    """
    if w_zero_code is None:
        w_zero_code = 1 << (bits - 1)
    cpw = ref.CODES_PER_WORD[bits]
    m, k = a_codes.shape
    n, k2 = w_codes.shape
    assert k == k2
    mp = -(-m // BM) * BM
    np_ = -(-n // BN) * BN
    kp = -(-k // cpw) * cpw
    a_pad = jnp.zeros((mp, kp), jnp.int32).at[:m, :k].set(a_codes)
    w_pad = jnp.full((np_, kp), w_zero_code, jnp.int32).at[:n, :k].set(w_codes)
    # Padded a-columns meet w_zero_code (value 0) → zero products; padded
    # a-rows/w-rows are sliced away below.
    w_pad = w_pad.at[:, k:].set(w_zero_code)
    out = lut_gemm_packed(
        ref.pack_codes(a_pad, bits), ref.pack_codes(w_pad, bits), lut, bits, use_onehot
    )
    return out[:m, :n]
