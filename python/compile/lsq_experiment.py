"""Tab. 1 analogue: LSQ accuracy at 32/8/2-bit on the synthetic dataset.

Paper (ImageNet, ResNet/VGG): 8-bit ≈ FP32; 2-bit a few points behind.
This reproduces the *shape* of that result with the same quantizer on the
offline substitute task (DESIGN.md §6.1).

    python -m compile.lsq_experiment [--steps N]
"""

import json
import os
import sys

from . import lsq


def main():
    steps = 300
    if "--steps" in sys.argv:
        steps = int(sys.argv[sys.argv.index("--steps") + 1])
    rows = []
    for bits in (32, 8, 2):
        acc, losses = lsq.train(bits=bits, steps=steps, noise=1.2, verbose=True)
        print(f"bits={bits:<3} test_acc={acc:.3f} final_loss={losses[-1]:.3f}")
        rows.append({"bits": bits, "test_acc": acc, "final_loss": losses[-1],
                     "loss_curve": losses[:: max(1, len(losses) // 50)]})
    os.makedirs("../bench_results", exist_ok=True)
    out = {
        "title": "Tab1-analog: LSQ accuracy vs precision (synthetic 10-class)",
        "paper_reference": {
            "resnet18": {"32": 0.705, "8": 0.711, "2": 0.679},
            "note": "paper Tab.1 ImageNet top-1; shape to match: 8bit≈fp32, 2bit a few points below",
        },
        "rows": rows,
    }
    path = "../bench_results/tab1_lsq.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    # Shape assertions (soft): 8-bit within 3 points of fp32.
    accs = {r["bits"]: r["test_acc"] for r in rows}
    assert accs[8] >= accs[32] - 0.05, f"8-bit dropped too far: {accs}"
    assert accs[2] >= 0.3, f"2-bit LSQ failed to learn: {accs}"
    print("shape check OK: 8-bit ~ fp32, 2-bit trails but learns")


if __name__ == "__main__":
    main()
