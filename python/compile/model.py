"""L2 — the quantized model graph in JAX, built on the L1 Pallas kernels.

`QuantConv2d` lowers convolution to im2col + the packed LUT GEMM — the
same pipeline as the rust engine (quantize → im2col → pack → Lut-Conv →
dequantize), so the AOT artifacts exercise every stage. `SmallCNN` is the
model lowered to HLO for the rust PJRT runtime (and the LSQ experiment's
backbone).
"""

import jax
import jax.numpy as jnp

from .kernels import lut_gemm, ref


def im2col(x, kh, kw, stride, pad):
    """NCHW (1, C, H, W) → (M, K) patches, K = C·kh·kw (matching the rust
    engine's column order: channel-major, then ky, kx)."""
    n, c, h, w = x.shape
    assert n == 1
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (1, C*kh*kw, OH, OW) with K ordered (c, ky, kx)
    k = c * kh * kw
    return patches.reshape(k, -1).T  # (M, K)


class QuantConv2d:
    """2-bit (by default) LUT-GEMM convolution with uniform quantizers.

    Weights: symmetric signed codes; activations: unsigned (post-ReLU)
    codes. The LUT stores centered integer products; dequant multiplies
    by the scale product — identical semantics to the rust engine.
    """

    def __init__(self, key, in_ch, out_ch, k, stride=1, pad=0, bits=2, relu=True):
        self.in_ch, self.out_ch, self.k = in_ch, out_ch, k
        self.stride, self.pad, self.bits, self.relu = stride, pad, bits, relu
        wkey, bkey = jax.random.split(key)
        fan_in = in_ch * k * k
        self.weight = jax.random.normal(wkey, (out_ch, fan_in)) * (2.0 / fan_in) ** 0.5
        self.bias = jax.random.uniform(bkey, (out_ch,), minval=-0.05, maxval=0.05)
        # Offline weight quantization.
        self.w_scale = float(jnp.max(jnp.abs(self.weight))) / (1 << (bits - 1)) + 1e-12
        self.w_zp = 1 << (bits - 1)
        self.w_codes = ref.quantize_ref(self.weight, self.w_scale, self.w_zp, bits)

    def lut_for(self, a_zp):
        wv = jnp.arange(1 << self.bits, dtype=jnp.int32) - self.w_zp
        av = jnp.arange(1 << self.bits, dtype=jnp.int32) - a_zp
        return ref.make_lut(wv, av, self.bits)

    def __call__(self, x, a_scale, a_zp, use_pallas=True):
        """x: (1, C, H, W) f32. Returns (1, out_ch, OH, OW) f32."""
        n, c, h, w = x.shape
        oh = (h + 2 * self.pad - self.k) // self.stride + 1
        ow = (w + 2 * self.pad - self.k) // self.stride + 1
        cols = im2col(x, self.k, self.k, self.stride, self.pad)  # (M, K)
        a_codes = ref.quantize_ref(cols, a_scale, a_zp, self.bits)
        lut = self.lut_for(a_zp)
        if use_pallas:
            acc = lut_gemm.lut_gemm(
                a_codes, self.w_codes, lut, self.bits, w_zero_code=self.w_zp
            )
        else:
            acc = ref.lut_gemm_ref(a_codes, self.w_codes, lut, self.bits)
        y = acc.astype(jnp.float32) * (self.w_scale * a_scale) + self.bias[None, :]
        y = y.T.reshape(1, self.out_ch, oh, ow)
        return jnp.maximum(y, 0.0) if self.relu else y


class SmallCNN:
    """Quantized small CNN (3 convs + GAP + linear head) — the model
    artifact lowered for the rust PJRT runtime."""

    def __init__(self, key, num_classes=10, bits=2, in_hw=16):
        keys = jax.random.split(key, 4)
        self.in_hw = in_hw
        self.convs = [
            QuantConv2d(keys[0], 3, 8, 3, stride=1, pad=1, bits=bits),
            QuantConv2d(keys[1], 8, 16, 3, stride=2, pad=1, bits=bits),
            QuantConv2d(keys[2], 16, 32, 3, stride=2, pad=1, bits=bits),
        ]
        # Per-layer activation quantizers: input is in [-1, 1]; later
        # activations are post-ReLU. Scales are rough static calibrations
        # (the LSQ experiment learns them instead).
        self.act_q = [(2.0 / 3, 2), (1.0, 0), (1.0, 0)]
        self.fc_w = jax.random.normal(keys[3], (num_classes, 32)) * (1.0 / 32) ** 0.5
        self.fc_b = jnp.zeros((num_classes,))

    def __call__(self, x, use_pallas=True):
        for conv, (s, zp) in zip(self.convs, self.act_q):
            x = conv(x, s, zp, use_pallas=use_pallas)
        x = x.mean(axis=(2, 3))  # (1, C)
        return x @ self.fc_w.T + self.fc_b[None, :]


def quant_gemm_pipeline(a, w, bits=2):
    """Float-in/float-out quantized GEMM: the artifact function for the
    per-shape PJRT benchmarks. `a`: (M, K) f32, `w`: (N, K) f32."""
    a_scale = 1.0 / ((1 << bits) - 1)
    a_zp = 0
    w_scale = 1.0 / (1 << (bits - 1))
    w_zp = 1 << (bits - 1)
    a_codes = ref.quantize_ref(a, a_scale, a_zp, bits)
    w_codes = ref.quantize_ref(w, w_scale, w_zp, bits)
    wv = jnp.arange(1 << bits, dtype=jnp.int32) - w_zp
    av = jnp.arange(1 << bits, dtype=jnp.int32) - a_zp
    lut = ref.make_lut(wv, av, bits)
    acc = lut_gemm.lut_gemm(a_codes, w_codes, lut, bits, w_zero_code=w_zp)
    return acc.astype(jnp.float32) * (a_scale * w_scale)
