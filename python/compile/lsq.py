"""LSQ — Learned Step Size Quantization (Esser et al. [10]), the training
side of the paper's Tab. 1.

Implements the LSQ quantizer with its custom gradient (straight-through
estimator for the rounding; the step-size gradient of Eq. 3 of the LSQ
paper with the 1/sqrt(N·Qp) gradient scale), a small convnet, and a
training loop on a synthetic 10-class image dataset (the offline
substitute for ImageNet — see DESIGN.md §6.1).

Run the Tab. 1 analogue with:  python -m compile.lsq_experiment
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------- LSQ core
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_quantize(v, s, qn, qp):
    """Fake-quantize v with learned step s: s * clip(round(v/s), -qn, qp)."""
    return jnp.clip(jnp.round(v / s), -qn, qp) * s


def _lsq_fwd(v, s, qn, qp):
    return lsq_quantize(v, s, qn, qp), (v, s)


def _lsq_bwd(qn, qp, res, g):
    v, s = res
    vs = v / s
    inside = (vs > -qn) & (vs < qp)
    # dL/dv: straight-through inside the clip range.
    dv = jnp.where(inside, g, 0.0)
    # dL/ds per LSQ Eq. 3.
    ds_elem = jnp.where(
        vs <= -qn,
        -float(qn),
        jnp.where(vs >= qp, float(qp), jnp.round(vs) - vs),
    )
    gscale = 1.0 / np.sqrt(v.size * max(qp, 1))
    ds = jnp.sum(g * ds_elem) * gscale
    return dv, ds


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def quant_ranges(bits, signed):
    """(qn, qp) code magnitudes for LSQ."""
    if signed:
        return (1 << (bits - 1)), (1 << (bits - 1)) - 1
    return 0, (1 << bits) - 1


def init_step(x, bits, signed):
    """LSQ step initialisation: 2·E|x| / sqrt(Qp)."""
    _, qp = quant_ranges(bits, signed)
    return 2.0 * jnp.mean(jnp.abs(x)) / np.sqrt(max(qp, 1))


# ------------------------------------------------------------ the network
def init_params(key, num_classes=10, width=16):
    k = jax.random.split(key, 5)
    he = lambda kk, shape, fan: jax.random.normal(kk, shape) * (2.0 / fan) ** 0.5
    w1 = he(k[0], (width, 3, 3, 3), 27)
    w2 = he(k[1], (2 * width, width, 3, 3), width * 9)
    w3 = he(k[2], (2 * width, 2 * width, 3, 3), 2 * width * 9)
    fc = he(k[3], (num_classes, 2 * width), 2 * width)
    params = {
        "w1": w1, "b1": jnp.zeros(width),
        "w2": w2, "b2": jnp.zeros(2 * width),
        "w3": w3, "b3": jnp.zeros(2 * width),
        "fc": fc, "fcb": jnp.zeros(num_classes),
        # Learned steps: one per quantized tensor (3 weight + 3 act).
        "sw": jnp.array([init_step(w1, 2, True), init_step(w2, 2, True), init_step(w3, 2, True)]),
        "sa": jnp.array([0.1, 0.1, 0.1]),
    }
    return params


def conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    return y + b[None, :, None, None]


def forward(params, x, bits):
    """bits: 32 (fp), 8 or 2. Activations quantize unsigned (post-ReLU
    inputs are shifted to ≥ 0 by the preceding ReLU); weights signed."""
    quant_w = bits < 32
    wq, aq = [], []
    if quant_w:
        qn_w, qp_w = quant_ranges(bits, True)
        _, qp_a = quant_ranges(bits, False)
        for i, name in enumerate(["w1", "w2", "w3"]):
            wq.append(lsq_quantize(params[name], params["sw"][i], qn_w, qp_w))
            aq.append((params["sa"][i], qp_a))
    else:
        wq = [params["w1"], params["w2"], params["w3"]]

    h = x
    strides = [1, 2, 2]
    for i in range(3):
        if quant_w:
            # Quantize the conv input (unsigned after first layer's tanh-ish
            # range; LSQ unsigned clips negatives to 0 like ReLU would).
            s, qp_a = aq[i]
            h = lsq_quantize(h, s, 0, qp_a)
        h = conv(h, wq[i], params[f"b{i+1}"], strides[i])
        h = jax.nn.relu(h)
    h = h.mean(axis=(2, 3))
    return h @ params["fc"].T + params["fcb"][None, :]


# --------------------------------------------------------------- data/train
def synthetic_dataset(key, n_per_class=400, classes=10, hw=16, noise=0.35):
    """Separable-but-noisy synthetic images: smooth class prototypes plus
    gaussian noise (the offline ImageNet stand-in)."""
    kp, kn, ks = jax.random.split(key, 3)
    freq = jax.random.normal(kp, (classes, 3, 4))  # low-freq coefficients
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, hw), jnp.linspace(0, 1, hw), indexing="ij")
    basis = jnp.stack(
        [jnp.sin(2 * np.pi * yy), jnp.cos(2 * np.pi * xx),
         jnp.sin(4 * np.pi * xx * yy), jnp.cos(2 * np.pi * (xx + yy))]
    )  # (4, H, W)
    protos = jnp.einsum("kcf,fhw->kchw", freq, basis)  # (classes, 3, H, W)
    n = classes * n_per_class
    labels = jnp.repeat(jnp.arange(classes), n_per_class)
    noise_imgs = jax.random.normal(kn, (n, 3, hw, hw)) * noise
    imgs = protos[labels] + noise_imgs
    perm = jax.random.permutation(ks, n)
    return imgs[perm], labels[perm]


def loss_fn(params, x, y, bits):
    logits = forward(params, x, bits)
    onehot = jax.nn.one_hot(y, logits.shape[-1])
    return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))


def accuracy(params, x, y, bits, batch=256):
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = forward(params, x[i : i + batch], bits)
        correct += int((logits.argmax(-1) == y[i : i + batch]).sum())
    return correct / x.shape[0]


def train(bits, steps=300, batch=64, lr=3e-3, seed=0, n_per_class=300, noise=1.2, verbose=False):
    """Train the small convnet at the given precision; returns (test_acc,
    loss_history)."""
    key = jax.random.PRNGKey(seed)
    kd, kp, kb = jax.random.split(key, 3)
    x, y = synthetic_dataset(kd, n_per_class=n_per_class, noise=noise)
    n_test = x.shape[0] // 5
    xtr, ytr = x[n_test:], y[n_test:]
    xte, yte = x[:n_test], y[:n_test]
    params = init_params(kp)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn), static_argnames=("bits",))
    # Adam.
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    losses = []
    for step in range(steps):
        kb, ks = jax.random.split(kb)
        idx = jax.random.randint(ks, (batch,), 0, xtr.shape[0])
        loss, g = grad_fn(params, xtr[idx], ytr[idx], bits)
        losses.append(float(loss))
        b1, b2, eps = 0.9, 0.999, 1e-8
        t = step + 1
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        params = jax.tree.map(
            lambda p, mm, vv: p
            - lr * (mm / (1 - b1**t)) / (jnp.sqrt(vv / (1 - b2**t)) + eps),
            params,
            m,
            v,
        )
        # Steps must stay positive.
        params["sw"] = jnp.maximum(params["sw"], 1e-5)
        params["sa"] = jnp.maximum(params["sa"], 1e-5)
        if verbose and step % 50 == 0:
            print(f"  step {step:4d} loss {loss:.3f}")
    return accuracy(params, xte, yte, bits), losses
