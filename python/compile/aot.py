"""AOT lowering: JAX (L2, calling the L1 Pallas kernels) → HLO **text**
artifacts + manifest + golden I/O for the rust PJRT runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the published
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo and DESIGN.md.

Usage: (cd python && python -m compile.aot --out ../artifacts)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print_large_constants. The default HLO printer elides big
    # array literals as `constant({...})`, which the consuming parser
    # accepts but fills with ZEROS — silently corrupting any module with
    # embedded weights/LUTs (we found this as exact-zero LUT rows in the
    # rust golden checks; see EXPERIMENTS.md §Debug-log).
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-jax metadata attributes (source_end_line etc.) are rejected by
    # the older HLO parser — strip metadata entirely.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def tensor_meta(arrs):
    return [{"shape": list(a.shape), "dtype": "f32"} for a in arrs]


def emit(out_dir, name, fn, example_inputs, tags):
    """Lower fn at the example shapes, write HLO + golden, return the
    manifest entry."""
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in example_inputs]
    lowered = jax.jit(fn).lower(*specs)
    hlo = to_hlo_text(lowered)
    hlo_file = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_file), "w") as f:
        f.write(hlo)
    # Golden: run the jitted fn on the example inputs.
    outputs = jax.jit(fn)(*example_inputs)
    if not isinstance(outputs, (tuple, list)):
        outputs = (outputs,)
    golden_file = f"{name}.golden.json"
    with open(os.path.join(out_dir, golden_file), "w") as f:
        json.dump(
            {
                "inputs": [np.asarray(a).reshape(-1).astype(float).tolist() for a in example_inputs],
                "outputs": [np.asarray(o).reshape(-1).astype(float).tolist() for o in outputs],
            },
            f,
        )
    print(f"  {name}: hlo {len(hlo)/1e3:.0f} kB, outputs {[tuple(o.shape) for o in outputs]}")
    return {
        "name": name,
        "hlo": hlo_file,
        "inputs": tensor_meta(example_inputs),
        "outputs": tensor_meta(outputs),
        "golden": golden_file,
        "tags": tags,
    }


#: Quantized-GEMM artifact shapes (M, N, K) — small conv-layer-like sizes
#: kept modest so interpret-mode lowering stays compact.
GEMM_SHAPES = [(8, 16, 64), (16, 32, 144), (32, 32, 576)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    key = jax.random.PRNGKey(0)
    entries = []

    print("lowering quant-GEMM artifacts (L1 pallas lut kernel)...")
    for m, n, k in GEMM_SHAPES:
        ka, kw = jax.random.split(jax.random.fold_in(key, m * n * k))
        a = jax.random.uniform(ka, (m, k), minval=0.0, maxval=1.0)
        w = jax.random.normal(kw, (n, k)) * 0.5
        entries.append(
            emit(
                out_dir,
                f"quant_gemm_m{m}_n{n}_k{k}_w2a2",
                lambda a, w: (model_lib.quant_gemm_pipeline(a, w, bits=2),),
                [a, w],
                {"kernel": "lut_gemm", "bits": "2", "m": str(m), "n": str(n), "k": str(k)},
            )
        )

    print("lowering small_cnn model artifact (L2 graph over L1 kernels)...")
    cnn = model_lib.SmallCNN(jax.random.PRNGKey(7), num_classes=10, bits=2, in_hw=16)
    x = jax.random.uniform(jax.random.PRNGKey(11), (1, 3, 16, 16), minval=-1.0, maxval=1.0)
    entries.append(
        emit(
            out_dir,
            "small_cnn_w2a2",
            lambda x: (cnn(x),),
            [x],
            {"kernel": "model", "bits": "2", "model": "small_cnn"},
        )
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": entries}, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()
