"""LSQ quantizer tests: forward semantics, custom gradients, and a short
end-to-end training smoke test (the full Tab. 1 analogue runs via
`python -m compile.lsq_experiment`)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import lsq


def test_lsq_forward_quantizes_to_grid():
    v = jnp.asarray([-1.0, -0.3, 0.0, 0.26, 0.9])
    s = jnp.asarray(0.25)
    out = lsq.lsq_quantize(v, s, 2, 1)  # 2-bit signed: qn=2, qp=1
    # codes clip to [-2, 1] → values in {-0.5, -0.25, 0, 0.25}.
    np.testing.assert_allclose(np.asarray(out), [-0.5, -0.25, 0.0, 0.25, 0.25], atol=1e-7)


def test_lsq_unsigned_clips_negatives():
    v = jnp.asarray([-0.5, 0.0, 0.4, 2.0])
    out = lsq.lsq_quantize(v, jnp.asarray(0.5), 0, 3)
    np.testing.assert_allclose(np.asarray(out), [0.0, 0.0, 0.5, 1.5], atol=1e-7)


def test_lsq_gradient_is_ste_inside_range():
    v = jnp.asarray([0.1, 0.2, -0.1])
    s = jnp.asarray(0.25)
    g = jax.grad(lambda v, s: jnp.sum(lsq.lsq_quantize(v, s, 2, 1)), argnums=0)(v, s)
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 1.0])
    # Outside the clip range the value gradient must vanish.
    v2 = jnp.asarray([10.0, -10.0])
    g2 = jax.grad(lambda v, s: jnp.sum(lsq.lsq_quantize(v, s, 2, 1)), argnums=0)(v2, s)
    np.testing.assert_allclose(np.asarray(g2), [0.0, 0.0])


def test_lsq_step_gradient_signs():
    """At the clip boundaries the step gradient takes the LSQ form
    (-qn / qp), inside it is (round(v/s) - v/s)·g — all scaled by
    1/sqrt(N·qp)."""
    s = jnp.asarray(0.25)
    gscale = 1.0 / np.sqrt(1 * 1)

    def gs(v):
        return float(
            jax.grad(lambda vv, ss: jnp.sum(lsq.lsq_quantize(vv, ss, 2, 1)), argnums=1)(
                jnp.asarray([v]), s
            )
        )

    assert np.isclose(gs(10.0), 1.0 * gscale)  # qp side
    assert np.isclose(gs(-10.0), -2.0 * gscale)  # -qn side
    # Inside: v = 0.3, v/s = 1.2 → clipped to qp=1 boundary... use
    # v/s = 0.6 → round 1, ds = (1 - 0.6) = 0.4.
    assert np.isclose(gs(0.15), 0.4 * gscale, atol=1e-6)


def test_init_step_positive_scales_with_data():
    x = jnp.asarray([0.5, -0.5, 1.0])
    s2 = lsq.init_step(x, 2, True)
    s4 = lsq.init_step(x, 4, True)
    assert float(s2) > float(s4) > 0


def test_synthetic_dataset_separable_and_balanced():
    x, y = lsq.synthetic_dataset(jax.random.PRNGKey(0), n_per_class=20, classes=4)
    assert x.shape == (80, 3, 16, 16)
    counts = np.bincount(np.asarray(y), minlength=4)
    np.testing.assert_array_equal(counts, [20] * 4)


def test_short_training_learns_fp32_and_2bit():
    acc32, losses32 = lsq.train(bits=32, steps=60, n_per_class=60, seed=1)
    acc2, losses2 = lsq.train(bits=2, steps=60, n_per_class=60, seed=1)
    # Loss must drop materially and accuracy beat chance (0.1) clearly.
    assert losses32[-1] < losses32[0] * 0.8
    assert acc32 > 0.3, acc32
    assert losses2[-1] < losses2[0]
    assert acc2 > 0.2, acc2
