"""Unit + property tests for the pure-jnp reference layer."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_pack_unpack_roundtrip_exact(bits):
    rng = np.random.default_rng(bits)
    cpw = ref.CODES_PER_WORD[bits]
    codes = jnp.asarray(rng.integers(0, 1 << bits, (5, cpw * 7)), jnp.int32)
    words = ref.pack_codes(codes, bits)
    assert words.shape == (5, 7)
    back = ref.unpack_codes(words, bits, codes.shape[1])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4]),
    rows=st.integers(1, 6),
    words=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_pack_unpack_roundtrip_property(bits, rows, words, seed):
    rng = np.random.default_rng(seed)
    k = words * ref.CODES_PER_WORD[bits]
    codes = jnp.asarray(rng.integers(0, 1 << bits, (rows, k)), jnp.int32)
    back = ref.unpack_codes(ref.pack_codes(codes, bits), bits, k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_make_lut_matches_products():
    wv = jnp.asarray([-2, -1, 0, 1], jnp.int32)
    av = jnp.asarray([0, 1, 2, 3], jnp.int32)
    lut = ref.make_lut(wv, av, 2)
    assert lut.shape == (16,)
    for cw in range(4):
        for ca in range(4):
            assert int(lut[(cw << 2) | ca]) == int(wv[cw]) * int(av[ca])


def test_lut_gemm_ref_hand_example():
    # a = [[0,1,2,3]], w = [[3,3,3,3]] signed weights (value 1), unsigned a.
    a = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    w = jnp.asarray([[3, 3, 3, 3]], jnp.int32)
    lut = ref.make_lut(jnp.arange(4, dtype=jnp.int32) - 2, jnp.arange(4, dtype=jnp.int32), 2)
    out = ref.lut_gemm_ref(a, w, lut, 2)
    assert out.shape == (1, 1)
    assert int(out[0, 0]) == 0 + 1 + 2 + 3


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31), bits=st.sampled_from([2, 3, 4]))
def test_lut_gemm_ref_equals_dense_dot(seed, bits):
    """LUT GEMM over centered codebooks == plain integer matmul of the
    centered code values."""
    rng = np.random.default_rng(seed)
    m, n, k = rng.integers(1, 6), rng.integers(1, 6), rng.integers(1, 40)
    a = jnp.asarray(rng.integers(0, 1 << bits, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 1 << bits, (n, k)), jnp.int32)
    zp = 1 << (bits - 1)
    lut = ref.make_lut(
        jnp.arange(1 << bits, dtype=jnp.int32) - zp,
        jnp.arange(1 << bits, dtype=jnp.int32),
        bits,
    )
    got = ref.lut_gemm_ref(a, w, lut, bits)
    want = (a[:, None, :] * (w[None, :, :] - zp)).sum(-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_ref_clips_and_rounds():
    x = jnp.asarray([[-10.0, -0.26, -0.24, 0.0, 0.24, 0.26, 10.0]])
    codes = ref.quantize_ref(x, 0.5, 2, 2)
    np.testing.assert_array_equal(
        np.asarray(codes)[0], [0, 1, 2, 2, 2, 3, 3]
    )


def test_quantize_dequantize_error_bound():
    rng = np.random.default_rng(9)
    # 2-bit signed grid spans [-2, 1]·scale = [-1.0, 0.5]; stay inside it
    # (edge clipping costs up to a full step and is tested elsewhere).
    x = jnp.asarray(rng.uniform(-0.95, 0.45, (4, 100)), jnp.float32)
    scale, zp, bits = 0.5, 2, 2
    codes = ref.quantize_ref(x, scale, zp, bits)
    back = ref.dequantize_ref(codes, scale, zp)
    # In-range values round to within half a step.
    assert float(jnp.max(jnp.abs(back - x))) <= scale / 2 + 1e-6


def test_quant_gemm_ref_tracks_float_gemm():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.uniform(0, 1, (8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.3, (4, 64)), jnp.float32)
    got = ref.quant_gemm_ref(a, w, 1.0 / 3, 0, 0.25, 2, 2)
    want = a @ w.T
    # 2-bit quantization: loose agreement, but correlation must be high.
    g, t = np.asarray(got).ravel(), np.asarray(want).ravel()
    corr = np.corrcoef(g, t)[0, 1]
    assert corr > 0.9, corr
