"""Pallas LUT-GEMM kernel vs the pure-jnp oracle (the core L1 correctness
signal), swept over shapes/bitwidths/codebooks with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lut_gemm, ref


def _lut(bits, signed_w=True, float_vals=False):
    zp = 1 << (bits - 1)
    wv = jnp.arange(1 << bits, dtype=jnp.int32) - (zp if signed_w else 0)
    av = jnp.arange(1 << bits, dtype=jnp.int32)
    lut = ref.make_lut(wv, av, bits)
    if float_vals:
        lut = lut.astype(jnp.float32) * 0.37
    return lut, (zp if signed_w else 0)


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("use_onehot", [False, True])
def test_pallas_matches_ref(bits, use_onehot):
    rng = np.random.default_rng(bits * 10 + use_onehot)
    m, n, k = 8, 8, 3 * ref.CODES_PER_WORD[bits]
    a = jnp.asarray(rng.integers(0, 1 << bits, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 1 << bits, (n, k)), jnp.int32)
    lut, zp = _lut(bits)
    want = ref.lut_gemm_ref(a, w, lut, bits)
    got = lut_gemm.lut_gemm(a, w, lut, bits, w_zero_code=zp, use_onehot=use_onehot)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    m=st.integers(1, 20),
    n=st.integers(1, 20),
    k=st.integers(1, 100),
)
def test_pallas_matches_ref_arbitrary_shapes_2bit(seed, m, n, k):
    """Padding wrapper: any (M, N, K), including non-multiples of the
    tile and packing sizes."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 4, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 4, (n, k)), jnp.int32)
    lut, zp = _lut(2)
    want = ref.lut_gemm_ref(a, w, lut, 2)
    got = lut_gemm.lut_gemm(a, w, lut, 2, w_zero_code=zp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unsigned_unsigned_codebooks():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 4, (5, 33)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 4, (6, 33)), jnp.int32)
    lut, _ = _lut(2, signed_w=False)
    want = ref.lut_gemm_ref(a, w, lut, 2)
    # unsigned weights: code 0 has value 0 → w_zero_code = 0.
    got = lut_gemm.lut_gemm(a, w, lut, 2, w_zero_code=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_float_lut_non_uniform():
    """f32 LUT entries (non-uniform quantization, paper §5.3)."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.integers(0, 4, (9, 50)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 4, (7, 50)), jnp.int32)
    wv = jnp.asarray([-1.7, -0.45, 0.0, 1.55], jnp.float32)  # code 2 ↦ 0.0
    av = jnp.asarray([0.0, 0.31, 0.9, 2.2], jnp.float32)
    lut = (wv[:, None] * av[None, :]).reshape(-1)
    want = ref.lut_gemm_ref(a, w, lut, 2)
    got = lut_gemm.lut_gemm(a, w, lut, 2, w_zero_code=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_max_accumulation_no_overflow():
    """Worst-case products at large K stay exact in i32."""
    k = 4096
    a = jnp.full((1, k), 3, jnp.int32)
    w = jnp.full((1, k), 3, jnp.int32)
    lut, _ = _lut(2, signed_w=False)
    got = lut_gemm.lut_gemm(a, w, lut, 2, w_zero_code=0)
    assert int(got[0, 0]) == 9 * k


def test_packed_entrypoint_requires_tiles():
    a = jnp.zeros((8, 4), jnp.int32)
    w = jnp.zeros((8, 4), jnp.int32)
    lut, _ = _lut(2)
    out = lut_gemm.lut_gemm_packed(a, w, lut, 2)
    assert out.shape == (8, 8)
    with pytest.raises(AssertionError):
        lut_gemm.lut_gemm_packed(jnp.zeros((7, 4), jnp.int32), w, lut, 2)
