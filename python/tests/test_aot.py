"""AOT pipeline tests: HLO text hygiene (the large-constant and metadata
pitfalls that corrupt the rust round-trip), manifest validity, golden
self-consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as ml


def _emit_one(tmp_path):
    cnn = ml.SmallCNN(jax.random.PRNGKey(1), num_classes=4, bits=2, in_hw=8)
    x = jax.random.uniform(jax.random.PRNGKey(2), (1, 3, 8, 8), minval=-1, maxval=1)
    entry = aot.emit(str(tmp_path), "t_model", lambda x: (cnn(x),), [x], {"k": "v"})
    return entry, cnn, x


def test_hlo_text_has_full_constants_and_no_metadata(tmp_path):
    entry, _, _ = _emit_one(tmp_path)
    text = open(os.path.join(tmp_path, entry["hlo"])).read()
    assert "constant({...})" not in text, "large constants were elided"
    assert "source_end_line" not in text, "new-parser-only metadata present"
    assert "ENTRY" in text


def test_manifest_entry_shape(tmp_path):
    entry, _, x = _emit_one(tmp_path)
    assert entry["name"] == "t_model"
    assert entry["inputs"] == [{"shape": [1, 3, 8, 8], "dtype": "f32"}]
    assert entry["outputs"][0]["shape"] == [1, 4]
    assert entry["tags"] == {"k": "v"}


def test_golden_self_consistency(tmp_path):
    """Golden outputs must equal re-running the jitted fn on the recorded
    inputs (guards against accidental nondeterminism in emit)."""
    entry, cnn, _ = _emit_one(tmp_path)
    g = json.load(open(os.path.join(tmp_path, entry["golden"])))
    x = jnp.asarray(np.array(g["inputs"][0], np.float32).reshape(1, 3, 8, 8))
    want = np.array(g["outputs"][0], np.float32)
    got = np.asarray(jax.jit(lambda x: (cnn(x),))(x)[0]).reshape(-1)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_quant_gemm_artifact_fn_deterministic(tmp_path):
    a = jax.random.uniform(jax.random.PRNGKey(3), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(4), (8, 32)) * 0.3
    y1 = ml.quant_gemm_pipeline(a, w, 2)
    y2 = jax.jit(lambda a, w: ml.quant_gemm_pipeline(a, w, 2))(a, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6, atol=1e-6)


def test_gemm_shapes_list_is_sane():
    for m, n, k in aot.GEMM_SHAPES:
        assert m % 8 == 0 and n % 8 == 0 and k % 16 == 0
