"""Pallas packing/quantization kernels vs the jnp references."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pack, ref


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_pack_pallas_matches_ref(bits):
    rng = np.random.default_rng(bits)
    cpw = ref.CODES_PER_WORD[bits]
    codes = jnp.asarray(rng.integers(0, 1 << bits, (6, cpw * 5)), jnp.int32)
    want = ref.pack_codes(codes, bits)
    got = pack.pack_pallas(codes, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), rows=st.integers(1, 8), words=st.integers(1, 6))
def test_pack_pallas_property(seed, rows, words):
    rng = np.random.default_rng(seed)
    k = words * 16
    codes = jnp.asarray(rng.integers(0, 4, (rows, k)), jnp.int32)
    got = pack.pack_pallas(codes, 2)
    back = ref.unpack_codes(got, 2, k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_quantize_pallas_matches_ref():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.uniform(-2, 2, (4, 64)), jnp.float32)
    for scale, zp, bits in [(0.5, 2, 2), (0.1, 0, 2), (0.05, 8, 4)]:
        want = ref.quantize_ref(x, scale, zp, bits)
        got = pack.quantize_pallas(x, scale, zp, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dequantize_pallas():
    acc = jnp.asarray([[1, -2, 300], [0, 7, -40]], jnp.int32)
    got = pack.dequantize_pallas(acc, 0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc) * 0.125)


def test_full_pipeline_quantize_pack_gemm():
    """quantize → pack (both Pallas) feeding the packed GEMM entrypoint
    equals the float-free reference chain."""
    from compile.kernels import lut_gemm

    rng = np.random.default_rng(21)
    m, n, k = 8, 8, 64
    a = jnp.asarray(rng.uniform(0, 1, (m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.4, (n, k)), jnp.float32)
    a_codes = pack.quantize_pallas(a, 1.0 / 3, 0, 2)
    w_codes = pack.quantize_pallas(w, 0.25, 2, 2)
    lut = ref.make_lut(
        jnp.arange(4, dtype=jnp.int32) - 2, jnp.arange(4, dtype=jnp.int32), 2
    )
    got = lut_gemm.lut_gemm_packed(
        pack.pack_pallas(a_codes, 2), pack.pack_pallas(w_codes, 2), lut, 2
    )
    want = ref.lut_gemm_ref(
        ref.quantize_ref(a, 1.0 / 3, 0, 2), ref.quantize_ref(w, 0.25, 2, 2), lut, 2
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
