"""L2 model tests: im2col correctness, QuantConv2d vs float conv,
SmallCNN pipeline consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as ml
from compile.kernels import ref


def test_im2col_matches_lax_conv():
    """im2col + dense GEMM == lax.conv for random f32 weights."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 3, 10, 10))
    w = jax.random.normal(jax.random.fold_in(key, 1), (5, 3, 3, 3))
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), ((1, 1), (1, 1)), dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    cols = ml.im2col(x, 3, 3, 1, 1)  # (M, K)
    got = (cols @ w.reshape(5, -1).T).T.reshape(1, 5, 10, 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_im2col_stride_and_pad():
    x = jnp.arange(1 * 1 * 4 * 4, dtype=jnp.float32).reshape(1, 1, 4, 4)
    cols = ml.im2col(x, 2, 2, 2, 0)
    assert cols.shape == (4, 4)
    # First patch = pixels (0,0),(0,1),(1,0),(1,1) = 0,1,4,5.
    np.testing.assert_array_equal(np.asarray(cols[0]), [0, 1, 4, 5])


def test_quantconv_pallas_equals_ref_path():
    conv = ml.QuantConv2d(jax.random.PRNGKey(1), 3, 6, 3, stride=1, pad=1, bits=2)
    x = jax.random.uniform(jax.random.PRNGKey(2), (1, 3, 8, 8), minval=-1, maxval=1)
    y_pallas = conv(x, 2.0 / 3, 2, use_pallas=True)
    y_ref = conv(x, 2.0 / 3, 2, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref), rtol=1e-5, atol=1e-5)
    assert y_pallas.shape == (1, 6, 8, 8)


def test_quantconv_tracks_float_conv():
    """2-bit conv correlates strongly with its float counterpart."""
    conv = ml.QuantConv2d(jax.random.PRNGKey(3), 3, 8, 3, stride=1, pad=1, bits=2, relu=False)
    x = jax.random.uniform(jax.random.PRNGKey(4), (1, 3, 12, 12), minval=0, maxval=1)
    y_q = conv(x, 1.0 / 3, 0)
    w4d = conv.weight.reshape(8, 3, 3, 3)
    y_f = jax.lax.conv_general_dilated(
        x, w4d, (1, 1), ((1, 1), (1, 1)), dimension_numbers=("NCHW", "OIHW", "NCHW")
    ) + conv.bias[None, :, None, None]
    corr = np.corrcoef(np.asarray(y_q).ravel(), np.asarray(y_f).ravel())[0, 1]
    assert corr > 0.85, corr


def test_small_cnn_shapes_and_determinism():
    cnn = ml.SmallCNN(jax.random.PRNGKey(5), num_classes=7, bits=2, in_hw=16)
    x = jax.random.uniform(jax.random.PRNGKey(6), (1, 3, 16, 16), minval=-1, maxval=1)
    y1 = cnn(x)
    y2 = cnn(x)
    assert y1.shape == (1, 7)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_small_cnn_pallas_vs_ref():
    cnn = ml.SmallCNN(jax.random.PRNGKey(7), num_classes=10, bits=2, in_hw=16)
    x = jax.random.uniform(jax.random.PRNGKey(8), (1, 3, 16, 16), minval=-1, maxval=1)
    yp = cnn(x, use_pallas=True)
    yr = cnn(x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), rtol=1e-4, atol=1e-4)


def test_quant_gemm_pipeline_shapes():
    a = jnp.ones((10, 20), jnp.float32) * 0.5
    w = jnp.ones((6, 20), jnp.float32) * -0.25
    out = ml.quant_gemm_pipeline(a, w, bits=2)
    assert out.shape == (10, 6)
    # All-equal inputs → all-equal outputs.
    assert float(jnp.std(out)) < 1e-6


def test_quantize_grid_is_exact_for_grid_inputs():
    """Inputs already on the dequant grid must round-trip exactly (the
    property that made tie-handling matter for the AOT goldens)."""
    scale, zp, bits = 0.25, 2, 2
    codes = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    x = ref.dequantize_ref(codes, scale, zp)
    back = ref.quantize_ref(x, scale, zp, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
